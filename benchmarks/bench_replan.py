"""Re-placement frontier: replan cadence x migration budget vs the best static plan.

Two overloaded re-placement scenarios (``regional-hotspot-replan``,
``failure-storm-replan``) run on one world and candidate pool.  The
backlog-driven controller of :mod:`repro.traffic.replan` is swept over
its two knobs —

* **cadence** (``period_slots``): how many topology-slot boundaries
  pass between decisions;
* **migration budget** (``migration_weight_s_per_mb``): the
  switching-cost gate, seconds of predicted gain demanded per MB of
  expert weights moved —

and every point lands on a goodput vs p99-TTFT frontier next to the
static candidates (which ride along in the same fleet sweep, common
random numbers).  The whole cadence x budget grid runs as **one fused
control-grid launch per scenario phase**
(:func:`~repro.traffic.replan.replan_traffic_fused` with the
``cadences`` / ``mig_weights`` axes — the joint control plane batches
the knob grid along the leading device axis) instead of the old
per-cell host-controller loop.  A ``periodic`` (backlog-blind) point
isolates what the live backlog signal buys.  The headline check is the
PR's acceptance criterion: backlog-driven replanning beats the best
static plan on goodput at matched (no worse) p99 TTFT under both
scenarios, storm phases combined.  CI uploads ``BENCH_replan.json``.

    PYTHONPATH=src python -m benchmarks.run --fast --only replan
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        baseline_plans, sample_topology)
from repro.traffic import (ReplanConfig, apply_failure_storm,
                           build_ground_segment, format_table, get_scenario,
                           replan_traffic_fused)

from .common import Timer, emit

#: Decision cadences tested (topology-slot boundaries per decision).
CADENCES_FAST = (1, 2)
CADENCES_FULL = (1, 2, 4)
#: Migration budgets tested (s of predicted gain demanded per MB moved).
MIG_WEIGHTS_FAST = (0.0, 0.05)
MIG_WEIGHTS_FULL = (0.0, 0.01, 0.1)
#: Overload multiplier (the frontier is only interesting past saturation).
RATE_SCALE = 9.0


def _world(fast: bool, seed: int = 0):
    """A roomy constellation (placement alternatives must exist) serving
    a short-request workload that saturates at RATE_SCALE."""
    ccfg = ConstellationConfig.scaled(12, 16, n_slots=12)
    con = Constellation(ccfg)
    link = LinkConfig()
    topo = sample_topology(con, link, np.random.default_rng(seed))
    activ = ActivationModel.zipf(4, 8, 2, seed=seed)
    wl = MoEWorkload.llama_moe_3p5b()
    ground = build_ground_segment(con, link, min_elevation_deg=10.0)
    return con, topo, activ, wl, ComputeConfig(), ground


def _scenario(name: str, fast: bool):
    horizon = 90.0 if fast else 180.0
    return dataclasses.replace(
        get_scenario(name),
        horizon_s=horizon, tail_s=60.0, slot_period_s=15.0, buffer_s=3.0,
        decode_mean=8, decode_max=16, prompt_median=4, prompt_max=16,
        failure_at_s=(horizon / 2.0
                      if get_scenario(name).failure_at_s is not None
                      else None))


def _phase_inputs(sc, plans, activ, rng, n_stations):
    """(tag, candidate pool, requests) per phase, mirroring
    ``run_scenario``'s storm split (requests drawn first, then the storm,
    so the rng stream matches the scenario runner's)."""
    requests = sc.requests(rng, n_stations, rate_scale=RATE_SCALE)
    if sc.failure_at_s is None:
        return [("main", plans, requests)]
    pre = requests.subset(requests.arrival_s < sc.failure_at_s)
    post = requests.subset(requests.arrival_s >= sc.failure_at_s)
    storm = apply_failure_storm(plans, activ, rng,
                                failure_frac=sc.failure_frac,
                                bytes_per_expert=1e6)
    phases = [("main", plans, pre)]
    if post.n_requests:
        phases.append(("post", storm.degraded_plans, post))
    return phases


def _combined(rows_by_phase: list[dict]) -> tuple[float, float]:
    """(goodput, p99 TTFT) over all phases: token-weighted goodput, worst
    phase p99 (the stricter matched-latency bound)."""
    tok = sum(r["goodput_tok_s"] * r["span_s"] for r in rows_by_phase)
    span = sum(r["span_s"] for r in rows_by_phase)
    p99s = [r["ttft_p99_s"] for r in rows_by_phase
            if np.isfinite(r["ttft_p99_s"])]
    return tok / span if span else 0.0, max(p99s) if p99s else float("nan")


def _collect(tag, res, rep, policy: str, knobs: dict) -> list[dict]:
    """Flatten one grid cell into frontier rows (replan row and every
    static candidate of the cell's common-random-numbers sweep)."""
    rows = []
    for p in res.plans:
        is_replan = p.plan_name.startswith("replan/")
        rows.append({
            "policy": policy if is_replan else "static",
            **(knobs if is_replan else
               {k: None for k in knobs}),
            "phase": tag,
            "plan": p.plan_name,
            "goodput_tok_s": round(p.goodput_tok_s, 3),
            "ttft_p99_s": round(p.quantile("ttft", 0.99), 3),
            "drop_rate": round(p.drop_rate, 4),
            "span_s": round(p.span_s, 3),
            "migration_mb": round(p.migration_bytes / 1e6, 3),
            "switches": rep.n_switches if (is_replan and rep) else 0,
        })
    return rows


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Sweep cadence x migration budget; emit the replan-vs-static
    frontier and the acceptance headline per scenario."""
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = baseline_plans(con, topo, activ, np.random.default_rng(3),
                           n_random_draws=2)
    cadences = CADENCES_FAST if fast else CADENCES_FULL
    weights = MIG_WEIGHTS_FAST if fast else MIG_WEIGHTS_FULL

    out: dict = {"fast": fast, "rate_scale": RATE_SCALE,
                 "candidates": [p.name for p in plans],
                 "cadences": list(cadences), "mig_weights": list(weights),
                 "grid_cells_per_launch": len(cadences) * len(weights)}
    all_rows: list[dict] = []
    headline = {}
    slot_period = con.cfg.orbital_period_s / topo.n_slots
    for name in ("regional-hotspot-replan", "failure-storm-replan"):
        sc = _scenario(name, fast)
        qcfg = dataclasses.replace(sc.queue_config(slot_period),
                                   migration_bytes_per_expert=1e6)
        rng = np.random.default_rng(11)
        phases = _phase_inputs(sc, plans, activ, rng, ground.n_stations)
        rows: list[dict] = []

        with Timer() as t:
            for tag, phase_plans, phase_req in phases:
                # The whole cadence x budget grid: ONE fused control
                # launch, cells cadence-major along the device axis.
                cells = replan_traffic_fused(
                    phase_plans, topo, activ, wl, comp, phase_req, rng,
                    ReplanConfig(mode="backlog"), qcfg, ground=ground,
                    cadences=list(cadences), mig_weights=list(weights))
                for ci, cad in enumerate(cadences):
                    for wi, w in enumerate(weights):
                        cell = cells[ci * len(weights) + wi]
                        rows += _collect(tag, cell.result, cell.report,
                                         "backlog",
                                         {"cadence": cad, "mig_weight": w})
                # Backlog-blind control point: what the live signal buys.
                per = replan_traffic_fused(
                    phase_plans, topo, activ, wl, comp, phase_req, rng,
                    ReplanConfig(mode="periodic"), qcfg, ground=ground)
                rows += _collect(tag, per.result, per.report, "periodic",
                                 {"cadence": 1, "mig_weight": 0.01})

        # Acceptance: best backlog point's combined goodput must beat the
        # best static candidate's at matched (no worse) p99 TTFT.
        def combined(policy, plan=None):
            sel = [r for r in rows if r["policy"] == policy
                   and (plan is None or r["plan"] == plan)]
            by_knob: dict = {}
            for r in sel:
                by_knob.setdefault(
                    (r["plan"], r.get("cadence"), r.get("mig_weight")),
                    []).append(r)
            return {k: _combined(v) for k, v in by_knob.items()}

        statics = combined("static")
        # One (goodput, p99) per static candidate: keep each candidate's
        # first sweep point (statics repeat identically across points).
        static_best = {}
        for (plan, _c, _w), gp in statics.items():
            static_best.setdefault(plan, gp)
        best_static_plan, (best_static_g, best_static_p99) = max(
            static_best.items(), key=lambda kv: kv[1][0])
        backlog_pts = combined("backlog")
        matched = {k: v for k, v in backlog_pts.items()
                   if not np.isfinite(best_static_p99)
                   or (np.isfinite(v[1]) and v[1] <= best_static_p99)}
        best_replan = max(matched.values(), key=lambda v: v[0],
                          default=(0.0, float("nan")))
        headline[name] = {
            "best_static_plan": best_static_plan,
            "best_static_goodput": round(best_static_g, 3),
            "best_static_ttft_p99_s": round(best_static_p99, 3),
            "best_replan_goodput_at_matched_p99": round(best_replan[0], 3),
            "replan_beats_static": bool(best_replan[0] > best_static_g),
        }
        all_rows += [{"scenario": name, **r} for r in rows]
        emit(f"replan/{name}", t.seconds * 1e6,
             f"replan={best_replan[0]:.3f};static={best_static_g:.3f};"
             f"beats={headline[name]['replan_beats_static']}")

    out["frontier"] = all_rows
    out["headline"] = headline
    # Console table: every replan point, but each static candidate only
    # once per (scenario, phase) — statics repeat identically across
    # sweep points.
    show, seen_static = [], set()
    for r in all_rows:
        if r["policy"] == "static":
            key = (r["scenario"], r["phase"], r["plan"])
            if key in seen_static:
                continue
            seen_static.add(key)
        show.append(r)
    print(format_table(show, prefix="# "))
    for name, h in headline.items():
        print(f"# {name}: replan {h['best_replan_goodput_at_matched_p99']} "
              f"vs static {h['best_static_goodput']} tok/s at p99 <= "
              f"{h['best_static_ttft_p99_s']}s -> "
              f"{'BEATS' if h['replan_beats_static'] else 'does not beat'}")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    run()
