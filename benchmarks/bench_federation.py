"""bench_federation — planet-scale federation fast path.

Two stages, both CI-gated through boolean flags (wall-clock, TTFT and
RSS *values* are recorded but skip-listed by tools/check_bench.py):

Hotspot overflow
    A K=3 federation with every request homed onto constellation 0 (a
    regional demand spike).  Federated overflow routing must beat the
    K-independent baseline (overflow off — bitwise identical to running
    each member alone, re-checked here) by ``GOODPUT_GAIN_MIN`` x
    goodput at matched p99 TTFT, and the whole comparison — nested
    2-entry sweep, every overflow round — must cost exactly one compile
    trace.

Million-user streaming
    A ``--fast``-scaled (2e5) / full (1e6+) user trace generated with
    :func:`repro.traffic.stream_requests` in bounded shards, served by a
    K=2 federation in one fused launch.  Gates: host prep wall-time
    (arrival streaming + per-lane chunk compaction) stays below the
    fused device wall-time, and peak RSS stays under the documented
    budget (see docs/architecture.md).

Any gate failure raises ``SystemExit`` so the CI smoke fails loudly.
"""
from __future__ import annotations

import json
import resource

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FederationConfig, FleetSim,
                           QueueConfig, RequestBatch, build_federation,
                           build_ground_segment, poisson_arrivals,
                           sample_decode_lens, sample_prompt_lens,
                           stream_requests)
from repro.traffic import queueing

from .common import Timer, emit

#: Federated-over-independent goodput floor under the hotspot.
GOODPUT_GAIN_MIN = 1.3
#: "Matched p99 TTFT": federated p99 may exceed independent p99 by at
#: most this factor.
P99_MATCH_FACTOR = 1.05
#: Documented peak-RSS budgets for the streaming stage (MB).
RSS_BUDGET_FAST_MB = 4096
RSS_BUDGET_FULL_MB = 8192

_WL = MoEWorkload.llama_moe_3p5b()
_COMP = ComputeConfig()


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _member_factory(seed: int, cfg: ConstellationConfig, req: RequestBatch,
                    qcfg: QueueConfig, n_layers: int, n_experts: int,
                    top_k: int):
    """Deterministic FleetSim factory (rebuildable on a shared bin grid)."""
    def build(min_bins: int = 0) -> FleetSim:
        con = Constellation(cfg)
        topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
        activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
        ground = build_ground_segment(con, LinkConfig(),
                                      min_elevation_deg=10.0)
        return FleetSim([spacemoe_plan(con, topo, activ)], topo, activ,
                        _WL, _COMP, req, np.random.default_rng(5),
                        qcfg=qcfg, ground=ground, min_bins=min_bins)
    return build


# --------------------------------------------------------------------- #
# Stage 1: hotspot overflow vs K independent constellations
# --------------------------------------------------------------------- #


def _hotspot_requests(horizon_s: float, rate_rps: float,
                      seed: int = 8) -> RequestBatch:
    rng = np.random.default_rng(seed)
    t = poisson_arrivals(rate_rps, horizon_s, rng)
    n = t.size
    return RequestBatch(
        arrival_s=t,
        prompt_len=sample_prompt_lens(n, rng, median=4, sigma=0.4,
                                      max_len=16),
        decode_len=sample_decode_lens(n, rng, mean=4, max_len=8),
        station=rng.integers(0, 8, n),
    )


def _parity_problems(fed, indep, masks) -> list[str]:
    """Overflow-off member outcomes must be bitwise identical to running
    each member's FleetSim alone on its home slice."""
    problems: list[str] = []
    fields = ("served", "shed", "retries", "ttft_s", "e2e_s", "tpot_s",
              "station_util", "token_total_s")

    def same(a: np.ndarray, b: np.ndarray) -> bool:
        # Bitwise, but NaN == NaN (unserved requests carry NaN latency).
        if np.issubdtype(np.asarray(a).dtype, np.floating):
            return np.array_equal(a, b, equal_nan=True)
        return np.array_equal(a, b)

    for s, res in enumerate(indep):
        for k, sim in enumerate(fed.sims):
            alone = sim.run(masks[s] & (fed.home == k))
            for pf, pa in zip(res.members[k].plans, alone.plans):
                for name in fields:
                    if not same(getattr(pf, name), getattr(pa, name)):
                        problems.append(
                            f"sweep {s} member {k} plan {pf.plan_name!r}: "
                            f"{name} differs from standalone run")
    return problems


def _run_hotspot(fast: bool) -> dict:
    cfg = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
    req = _hotspot_requests(60.0, 5.0)
    qcfg = QueueConfig(dt_s=0.05, tail_s=60.0,
                       admission=AdmissionConfig(ttft_target_s=10.0))
    # Regional spike: every request homed onto constellation 0.
    home = np.zeros(req.n_requests, dtype=np.int64)
    with Timer() as t_build:
        fed = build_federation(
            [_member_factory(s, cfg, req, qcfg, 4, 4, 2) for s in (0, 1, 2)],
            FederationConfig(overflow=True), home=home)

    # Nested 2-entry sweep: trace-pin check covers the sweep AND every
    # overflow round below (same shapes -> compile-cache hits).
    masks = np.stack([
        np.ones(req.n_requests, dtype=bool),
        np.random.default_rng(1).random(req.n_requests) < 0.7])
    traces0 = queueing.FUSED_TRACE_COUNT
    with Timer() as t_indep:
        indep = fed.run_many(masks, overflow=False)
    with Timer() as t_fed:
        federated = fed.run_many(masks, overflow=True)
    traces_used = queueing.FUSED_TRACE_COUNT - traces0

    problems = _parity_problems(fed, indep, masks)

    gi = indep[0].federated.goodput_tok_s
    gf = federated[0].federated.goodput_tok_s
    p99_i = indep[0].federated.quantile("ttft", 0.99)
    p99_f = federated[0].federated.quantile("ttft", 0.99)
    gain = gf / gi if gi > 0 else np.inf
    return {
        "n_members": len(fed.sims),
        "n_requests": int(req.n_requests),
        "goodput_indep_tok_s": round(float(gi), 3),
        "goodput_fed_tok_s": round(float(gf), 3),
        "goodput_gain_ratio": round(float(gain), 3),
        "ttft_p99_indep_s": round(float(p99_i), 3),
        "ttft_p99_fed_s": round(float(p99_f), 3),
        "n_shed_indep": int(indep[0].federated.shed.sum()),
        "n_shed_fed": int(federated[0].federated.shed.sum()),
        "n_rerouted": int((federated[0].hops > 0).sum()),
        "n_rounds": int(federated[0].n_rounds),
        "traces_used": int(traces_used),
        "build_wall_s": round(t_build.seconds, 3),
        "indep_wall_s": round(t_indep.seconds, 3),
        "fed_wall_s": round(t_fed.seconds, 3),
        "goodput_gain_ok": bool(gain >= GOODPUT_GAIN_MIN),
        "p99_matched_ok": bool(p99_f <= P99_MATCH_FACTOR * p99_i),
        "single_trace_ok": bool(traces_used == 1),
        "parity_ok": not problems,
        "parity_problems": problems,
    }


# --------------------------------------------------------------------- #
# Stage 2: million-user streaming trace in one fused launch
# --------------------------------------------------------------------- #


def _run_million(fast: bool) -> dict:
    n_target = 2.0e5 if fast else 1.01e6
    rate_max = 2500.0
    horizon_s = n_target / (0.96 * rate_max)
    budget_mb = RSS_BUDGET_FAST_MB if fast else RSS_BUDGET_FULL_MB

    with Timer() as t_stream:
        req, n_env = stream_requests(
            np.random.default_rng(0),
            lambda t: np.full_like(t, 0.96 * rate_max),
            rate_max, horizon_s, n_stations=8, shard_s=60.0,
            prompt_median=2, prompt_sigma=0.3, prompt_max=4,
            decode_mean=1, decode_max=2)

    cfg = ConstellationConfig.scaled(6, 8, n_slots=8, survival_prob=1.0)
    qcfg = QueueConfig(dt_s=0.5, tail_s=60.0,
                       admission=AdmissionConfig(ttft_target_s=20.0))
    with Timer() as t_build:
        fed = build_federation(
            [_member_factory(s, cfg, req, qcfg, 2, 2, 1) for s in (0, 1)])

    # Host prep (per-lane chunk compaction) vs device time, split via
    # FederationSim._prepare / _execute.
    K = len(fed.sims)
    offered = np.stack([fed.home == k for k in range(K)])[None]
    with Timer() as t_prep:
        prep = fed._prepare(offered)
    with Timer() as t_first:
        fed._execute(prep)           # compile + launch
    with Timer() as t_device:
        out = fed._execute(prep)     # steady-state device wall
    host_prep_s = t_stream.seconds + t_prep.seconds

    n_shed = int(sum((out["shed"][k, 0] & offered[0, k]).sum()
                     for k in range(K)))

    rss_mb = _peak_rss_mb()
    return {
        "n_users": int(req.n_requests),
        "n_envelope": int(n_env),
        "n_members": K,
        "n_bins": int(fed.n_bins),
        "n_shed_measured": n_shed,
        "stream_wall_s": round(t_stream.seconds, 3),
        "build_wall_s": round(t_build.seconds, 3),
        "prep_wall_s": round(t_prep.seconds, 3),
        "compile_wall_s": round(t_first.seconds, 3),
        "device_wall_s": round(t_device.seconds, 3),
        "host_prep_wall_s": round(host_prep_s, 3),
        "peak_rss_mb": round(rss_mb, 1),
        "rss_budget_mb": budget_mb,
        "prep_ok": bool(host_prep_s < t_device.seconds),
        "rss_ok": bool(rss_mb < budget_mb),
    }


# --------------------------------------------------------------------- #
# Driver
# --------------------------------------------------------------------- #


def run(fast: bool = True, json_path: str | None = None) -> dict:
    hotspot = _run_hotspot(fast)
    million = _run_million(fast)
    out = {"fast": fast, "hotspot": hotspot, "million": million}

    emit("federation_hotspot_gain",
         hotspot["goodput_gain_ratio"],
         f"goodput {hotspot['goodput_indep_tok_s']}->"
         f"{hotspot['goodput_fed_tok_s']} tok/s, "
         f"p99 ttft {hotspot['ttft_p99_indep_s']}->"
         f"{hotspot['ttft_p99_fed_s']}s, "
         f"{hotspot['n_rerouted']} rerouted in "
         f"{hotspot['n_rounds']} rounds, "
         f"{hotspot['traces_used']} trace")
    emit("federation_million_users", million["n_users"],
         f"host prep {million['host_prep_wall_s']}s vs device "
         f"{million['device_wall_s']}s, peak rss "
         f"{million['peak_rss_mb']}MB/{million['rss_budget_mb']}MB")

    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2, sort_keys=True)

    gates = {
        "hotspot.goodput_gain_ok": hotspot["goodput_gain_ok"],
        "hotspot.p99_matched_ok": hotspot["p99_matched_ok"],
        "hotspot.single_trace_ok": hotspot["single_trace_ok"],
        "hotspot.parity_ok": hotspot["parity_ok"],
        "million.prep_ok": million["prep_ok"],
        "million.rss_ok": million["rss_ok"],
    }
    failed = [name for name, ok in gates.items() if not ok]
    if failed:
        for p in hotspot["parity_problems"]:
            print(f"  parity: {p}")
        raise SystemExit(f"bench_federation: gate(s) failed: "
                         f"{', '.join(failed)}")
    return out


if __name__ == "__main__":
    run()
