"""Paper Fig. 6: per-layer latency mean/variance + E2E comparison per scheme.

(a) per-layer inference latency mean/variance per scheme;
(b) E2E token-generation latency comparison.
"""
from __future__ import annotations

import numpy as np

from repro.core import (rand_intra_cg_plan, rand_intra_plan, rand_place_plan,
                        simulate_token_generation, spacemoe_plan)

from .common import N_EXPERTS, N_LAYERS, Timer, emit, paper_world


def run(n_tokens: int = 600, seed: int = 0) -> dict:
    con, topo, activ, wl, comp = paper_world(seed=seed)
    ccfg = con.cfg
    plans = {
        "SpaceMoE": spacemoe_plan(con, topo, activ, wl, comp),
        "RandPlace": rand_place_plan(ccfg, N_LAYERS, N_EXPERTS,
                                     np.random.default_rng(seed + 1)),
        "RandIntra": rand_intra_plan(ccfg, N_LAYERS, N_EXPERTS,
                                     np.random.default_rng(seed + 2)),
        "RandIntra-CG": rand_intra_cg_plan(ccfg, N_LAYERS, N_EXPERTS,
                                           np.random.default_rng(seed + 3)),
    }
    out = {}
    for scheme, plan in plans.items():
        with Timer() as t:
            res = simulate_token_generation(
                plan, topo, activ, wl, comp, np.random.default_rng(5),
                n_tokens=n_tokens,
            )
        mean, std = res.layer_stats()
        out[scheme] = {
            "layer_mean_ms": (mean * 1e3).round(3).tolist(),
            "layer_std_ms": (std * 1e3).round(3).tolist(),
            "e2e_s": res.mean_s,
        }
        emit(
            f"fig6a/{scheme}", t.seconds / n_tokens * 1e6,
            f"layer_mean_ms={float(mean.mean()*1e3):.3f};"
            f"layer_std_ms={float(std.mean()*1e3):.3f};"
            f"e2e_s={res.mean_s:.4f}",
        )
    return out


if __name__ == "__main__":
    run()
