"""Calibration validation: Eq. 43 predictions vs real measured decode steps.

The tentpole check of the service-time calibration layer
(``repro.core.calibration``): build a host-unit :class:`ServiceModel`
from freshly measured kernels, run a *real* sharded decode on a plan's
expert placement — the actual router picks the experts, each satellite's
expert group is the real FFN executed on real weights, the gateway step
is the real decode-attention kernel — and assert the engine's Eq. 43
per-layer latency predictions (same injected draws, zero-latency
topology) match the measured step times within :data:`TOLERANCE`.

Per validated config the measured per-layer step time is assembled from
really-executed phases, token by token:

    step(t) = t_attn(B=1) + max_s  t_ffn(visits of token t on satellite s)

i.e. the satellites run their routed visits in parallel (critical path =
slowest satellite), each satellite runs its own visits serially — exactly
the Eq. 43 contention semantic ``max_k q * t_expert`` the engine
computes.  The prediction side is ``evaluate_plans(...,
service_model=host_units)`` with the router's draws injected, so both
sides see the identical expert assignment and colocation pattern; the
expert service number crosses two independent code paths (the table
times the ``gmm_ref`` chain on (E, C, d) buckets, the decode executes
``models.moe.expert_ffn`` on per-satellite groups).

Tolerance is CPU-grade: single-core wall timings jitter, and XLA CPU
picks different dot kernels for the table's batched (E, C, d) buckets
than for a group's 2D matmuls (up to ~2x apart in achieved bandwidth at
these sizes), so the gate is a *factor* bound (measured/predicted
per-layer mean within [1/TOLERANCE, TOLERANCE]), not a percentage one.
Observed worst factor on the reference container is ~1.7 (a systematic
measured/predicted ~0.6 from exactly that kernel-choice gap).

Fails hard (SystemExit) on deviation — CI runs this as the calibration
regression gate and diffs the JSON against a committed baseline.

    PYTHONPATH=src python -m benchmarks.bench_calibration \
        --json-out BENCH_calibration.json
    PYTHONPATH=src python -m benchmarks.bench_calibration --refresh
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, MoEWorkload,
                        PlacementPlan, ServiceModel, TopologySample,
                        evaluate_plans)
from repro.core import calibration as cal

from .common import emit

#: Configs the real-decode validation runs on (>= 2 per the issue).
HARNESS_ARCHS = ("deepseek-moe-16b", "llama-moe-3.5b")

#: Archs whose satellite-unit tables are committed under
#: ``repro/core/calibration_tables/`` (``--refresh`` regenerates them).
COMMITTED_ARCHS = ("deepseek-moe-16b", "llama-moe-3.5b")

#: Measured/predicted per-layer mean must satisfy 1/TOL <= ratio <= TOL.
#: Factor bound, not a percentage: single-core CPU timings jitter by tens
#: of percent, and the harness intentionally crosses two code paths
#: (gmm_ref buckets for the table vs concatenated 2D chains for the
#: decode) whose XLA CPU kernels differ by up to ~2x in achieved
#: bandwidth.  Worst observed factor is ~1.7; 2.5 leaves CI headroom.
TOLERANCE = 2.5

#: Attention context of the harness decode (matches the harness table, so
#: the gateway prediction is the exact measured lookup).
CTX = 256

HARNESS_BATCHES = (1, 2, 4)
N_LAYERS = 2
N_EXPERT_SATS = 3          # experts spread over sats 1..3 => colocation, q>1


def _harness_config(arch: str):
    """Widened smoke config: same MoE family, dims big enough that one
    expert visit (~25 MB of weight reads, milliseconds) dwarfs the jit
    dispatch overhead (~0.3 ms on a single slow core) — at smoke dims a
    visit times at ~the call overhead and the factor comparison would be
    meaningless."""
    from repro.configs import smoke_config
    cfg = smoke_config(arch)
    return dataclasses.replace(
        cfg, d_model=1024, d_ff_expert=2048, n_experts=4,
        top_k=min(cfg.top_k, 4), n_shared_experts=0, moe_slotting=False)


def _flat_topology(n_sats: int) -> TopologySample:
    """Fully-connected single-slot topology with ~zero hop latency, so
    the Eq. 43 comparison isolates the service terms."""
    edges = np.array([[i, j] for i in range(n_sats)
                      for j in range(i + 1, n_sats)], dtype=np.int64)
    return TopologySample(
        edges=edges,
        edge_mask=np.ones((1, len(edges)), dtype=bool),
        edge_latency=np.full((1, len(edges)), 1e-9),
        n_sats=n_sats,
    )


def _measure_real_decode(cfg, params, xs, draws, sat_of, iters: int):
    """Really execute the sharded decode, layer by layer, token by token.

    Returns (n_tokens, L) measured per-layer step seconds: the B=1
    decode-attention kernel plus the critical-path satellite FFN group.
    A satellite's group of v drawn experts runs as the concatenated 2D
    gated chain on the real weights —

        y = (silu(x @ Wg_cat) * (x @ Wu_cat)) @ Wd_cat

    with ``Wg_cat`` of shape (d, v*f) — mathematically the v expert FFNs
    on the shared token and the layout a sane serving runtime would pick.
    (The batched (v, 1, d) einsum formulation hits a pathological XLA CPU
    dot at v=1: ~50x slower than the identical 2D matmuls, which would
    measure the compiler's worst case rather than the satellite's work.)
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.ops import timed_call

    hkv, g_rep, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, \
        cfg.head_dim
    key = jax.random.PRNGKey(11)
    kq, kk = jax.random.split(key)
    q = jax.random.normal(kq, (1, hkv, g_rep, hd), jnp.float32)
    kv = jax.random.normal(kk, (1, hkv, CTX, hd), jnp.float32)
    pos = jnp.full((1,), CTX - 1, jnp.int32)
    t_attn = timed_call(jax.jit(ref.decode_attention_ref), q, kv, kv, pos,
                        iters=iters)

    d = cfg.d_model
    group = jax.jit(lambda x, wg, wu, wd:
                    (jax.nn.silu(x @ wg) * (x @ wu)) @ wd)

    n_tokens = draws.shape[1]
    out = np.zeros((n_tokens, N_LAYERS))
    for layer in range(N_LAYERS):
        p = params[layer]
        for t in range(n_tokens):
            groups: dict[int, list[int]] = {}
            for e in draws[layer, t]:
                groups.setdefault(int(sat_of[e]), []).append(int(e))
            x = xs[layer][t][None, :]                         # (1, d)
            t_exp = 0.0
            for elist in groups.values():
                sel = jnp.asarray(elist)
                wg = jnp.moveaxis(p["w_gate"][sel], 0, 1).reshape(d, -1)
                wu = jnp.moveaxis(p["w_up"][sel], 0, 1).reshape(d, -1)
                wd = p["w_down"][sel].reshape(-1, d)
                t_s = timed_call(group, x, wg, wu, wd, iters=iters)
                t_exp = max(t_exp, t_s)
            out[t, layer] = t_attn + t_exp
    return out


def validate_config(arch: str, n_tokens: int = 8, iters: int = 2) -> dict:
    """One config's measured-vs-predicted comparison; returns the record."""
    import jax
    import jax.numpy as jnp

    from repro.models.moe import moe_init, route

    cfg = _harness_config(arch)
    wl = MoEWorkload.from_model_config(cfg)
    compute = ComputeConfig()

    # Host-unit service model from a fresh measurement of this workload.
    # rows_per_expert=1 matches the B=1 decode semantic: every visit pays
    # its own weight read, same as the per-satellite groups below.
    measured = cal.measure_components(wl, CTX, HARNESS_BATCHES, impl="ref",
                                      iters=iters, rows_per_expert=1)
    table = cal.calibrate(f"{arch}-harness", wl, ctx_len=CTX,
                          batches=HARNESS_BATCHES, compute=compute,
                          measured=measured)
    svc = ServiceModel.calibrated(wl, compute, table, units="host")

    # Real MoE layers: real router picks the experts (= injected draws).
    key = jax.random.PRNGKey(7)
    keys = jax.random.split(key, 2 * N_LAYERS)
    params = [moe_init(keys[i], cfg, jnp.float32) for i in range(N_LAYERS)]
    xs = [jax.random.normal(keys[N_LAYERS + i], (n_tokens, cfg.d_model),
                            jnp.float32) for i in range(N_LAYERS)]
    draws = np.stack([
        np.asarray(route(cfg, params[i]["router"], xs[i])[1])
        for i in range(N_LAYERS)
    ])                                                   # (L, T, K)

    # Placement: gateway on sat 0, experts over sats 1..N_EXPERT_SATS —
    # colocation makes the Eq. 43 contention term q > 1 load-bearing.
    sat_of = 1 + np.arange(cfg.n_experts) % N_EXPERT_SATS
    plan = PlacementPlan(
        gateways=np.zeros(N_LAYERS, dtype=np.int64),
        expert_sats=np.tile(sat_of, (N_LAYERS, 1)),
        name=f"{arch}-harness",
    )
    topo = _flat_topology(1 + N_EXPERT_SATS)
    activ = ActivationModel.zipf(N_LAYERS, cfg.n_experts, cfg.top_k, seed=0)

    measured_tl = _measure_real_decode(cfg, params, xs, draws, sat_of, iters)
    res = evaluate_plans(
        [plan], topo, activ, wl, compute, np.random.default_rng(0),
        n_tokens=n_tokens, ctx_len=CTX, include_lm_head=False,
        slots=np.zeros(n_tokens, dtype=np.int64), draws=draws,
        service_model=svc,
    )[0]
    predicted_tl = res.layer_latency_s                   # (T, L)

    layers = []
    ok = True
    for layer in range(N_LAYERS):
        m = float(np.mean(measured_tl[:, layer]))
        p = float(np.mean(predicted_tl[:, layer]))
        ratio = m / p
        ok &= (1.0 / TOLERANCE) <= ratio <= TOLERANCE
        layers.append({"measured_s": m, "predicted_s": p,
                       "ratio": round(ratio, 4)})
    ratios = [ly["ratio"] for ly in layers]
    return {
        "config": arch,
        "n_tokens": n_tokens,
        "ctx_len": CTX,
        "tolerance": TOLERANCE,
        "table_hash": table.table_hash,
        "layers": layers,
        "worst_ratio": float(max(max(ratios), 1.0 / min(ratios))),
        "pass": bool(ok),
    }


def fleet_smoke() -> dict:
    """Calibrated FleetSim end-to-end smoke: one saturation point of the
    traffic world on the committed (or freshly built) llama-moe table."""
    from repro.traffic import FleetSim, get_scenario

    from .bench_traffic import _plans, _world

    con, topo, activ, wl, comp, ground = _world(True)
    try:
        table = cal.load_table("llama-moe-3.5b")
        source = "committed"
    except FileNotFoundError:
        table = cal.calibrate("llama-moe-3.5b", wl, ctx_len=CTX,
                              batches=HARNESS_BATCHES, compute=comp, iters=2)
        source = "fresh"
    svc = ServiceModel.calibrated(wl, comp, table)
    plans = _plans(con, topo, activ)[:1]
    sc = dataclasses.replace(get_scenario("smoke"), horizon_s=30.0,
                             tail_s=30.0, kv_slots=8)
    requests = sc.requests(np.random.default_rng(13), ground.n_stations,
                           rate_scale=2.0)
    slot_period = con.cfg.orbital_period_s / topo.n_slots
    sim = FleetSim(plans, topo, activ, wl, comp, requests,
                   np.random.default_rng(13),
                   qcfg=sc.queue_config(slot_period), ground=ground,
                   service_model=svc)
    res = sim.run_legacy()
    pl = res.plans[0]
    ttft = pl.quantile("ttft", 0.5)
    return {
        "table": source,
        "table_hash": table.table_hash,
        "plan": pl.plan_name,
        "ttft_p50_s": float(ttft),
        "goodput_tok_s": float(pl.goodput_tok_s),
        "finite": bool(np.isfinite(ttft)),
    }


def refresh_tables(ctx_len: int = 512, batches=(1, 2, 4, 8),
                   iters: int = 2) -> list[str]:
    """Regenerate the committed satellite-unit tables (full configs).

    ``rows_per_expert=2`` keeps the full-dim gmm chain tractable on a
    single CPU core; the derived satellite times depend on the measured
    *efficiency*, not the absolute bucket size.
    """
    from repro.configs import get_config

    compute = ComputeConfig()
    paths = []
    for arch in COMMITTED_ARCHS:
        wl = MoEWorkload.from_model_config(get_config(arch))
        measured = cal.measure_components(wl, ctx_len, tuple(batches),
                                          impl="ref", iters=iters,
                                          rows_per_expert=2)
        table = cal.calibrate(arch, wl, ctx_len=ctx_len,
                              batches=tuple(batches), compute=compute,
                              measured=measured)
        path = cal.save_table(table)
        paths.append(str(path))
        print(f"# wrote {path} (hash {table.table_hash})")
    return paths


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Validate every harness config + the fleet smoke; exits non-zero on
    any tolerance deviation (the CI calibration gate)."""
    n_tokens, iters = (8, 2) if fast else (16, 3)
    out: dict = {"tolerance": TOLERANCE, "configs": []}
    failed = []
    for arch in HARNESS_ARCHS:
        rec = validate_config(arch, n_tokens=n_tokens, iters=iters)
        out["configs"].append(rec)
        emit(f"calibration/{arch}",
             rec["layers"][0]["measured_s"] * 1e6,
             f"worst_ratio={rec['worst_ratio']:.3f};pass={rec['pass']}")
        if not rec["pass"]:
            failed.append(arch)
    out["fleet_calibrated"] = fleet_smoke()
    emit("calibration/fleet",
         out["fleet_calibrated"]["ttft_p50_s"] * 1e6,
         f"finite={out['fleet_calibrated']['finite']}")
    if not out["fleet_calibrated"]["finite"]:
        failed.append("fleet")
    out["pass"] = not failed
    out["_provenance"] = cal.provenance()

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {json_path}")
    if failed:
        raise SystemExit(
            f"bench_calibration: Eq. 43 predictions deviate beyond "
            f"{TOLERANCE}x on {failed} — recalibrate "
            f"(--refresh) or investigate the engine")
    return out


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json-out", default=None, metavar="PATH")
    ap.add_argument("--refresh", action="store_true",
                    help="regenerate the committed satellite-unit tables")
    args = ap.parse_args()
    if args.refresh:
        refresh_tables()
        return
    run(fast=args.fast, json_path=args.json_out)


if __name__ == "__main__":
    main()
