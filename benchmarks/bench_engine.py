"""Batched jit engine vs. legacy NumPy simulator: plan-sweep throughput.

The workload is the acceptance sweep: P placement plans x n tokens on the
paper constellation.  Legacy evaluates plans one at a time (rebuilding the
per-plan Dijkstra table each call, as the old API did); the engine builds
one deduped :class:`PlanBatch` table and runs a single vmapped pass.

Rows: per-path wall time, speedup, plans/sec and tokens/sec.
"""
from __future__ import annotations

import numpy as np

from repro.core import (PlanBatch, evaluate_plans, multi_expert_plan,
                        rand_intra_cg_plan, simulate_token_generation_legacy,
                        spacemoe_plan)

from .common import Timer, emit, paper_world


def sweep_plans(con, topo, activ, n_plans: int, seed: int = 0) -> list:
    """SpaceMoE + multi-expert modes + RandIntra-CG draws — the shape of a
    continuous re-placement sweep (fixed central gateways, varying expert
    assignments)."""
    rng = np.random.default_rng(seed)
    plans = [
        spacemoe_plan(con, topo, activ),
        multi_expert_plan(con, topo, activ, 2, "slotted"),
        multi_expert_plan(con, topo, activ, 2, "spread"),
    ]
    while len(plans) < n_plans:
        p = rand_intra_cg_plan(con.cfg, activ.n_layers, activ.n_experts, rng)
        p.name = f"{p.name}#{len(plans)}"
        plans.append(p)
    return plans[:n_plans]


def run(n_tokens: int = 1000, n_plans: int = 16, n_slots: int | None = None,
        cfg=None, check: bool = True) -> float:
    """Returns the engine-over-legacy speedup (and emits CSV rows)."""
    con, topo, activ, wl, comp = paper_world(n_slots=n_slots, cfg=cfg)
    plans = sweep_plans(con, topo, activ, n_plans)

    # Warm the jit cache on the real shapes so compile time is not billed
    # to the steady-state measurement (one-time cost per shape).
    warm_batch = PlanBatch.from_plans(plans, topo)
    evaluate_plans(plans, topo, activ, wl, comp, np.random.default_rng(1),
                   n_tokens=n_tokens, batch=warm_batch)

    with Timer() as t_leg:
        legacy = [
            simulate_token_generation_legacy(
                p, topo, activ, wl, comp, np.random.default_rng(1), n_tokens)
            for p in plans
        ]
    with Timer() as t_eng:
        # Cold sweep: includes building the deduped Dijkstra table.
        results = evaluate_plans(plans, topo, activ, wl, comp,
                                 np.random.default_rng(1), n_tokens=n_tokens)
    with Timer() as t_hot:
        # Hot sweep: table reused (the per-slot re-placement steady state).
        evaluate_plans(plans, topo, activ, wl, comp,
                       np.random.default_rng(1), n_tokens=n_tokens,
                       batch=warm_batch)

    if check:
        worst = max(
            abs(r.mean_s - l.mean_s) / l.mean_s
            for r, l in zip(results, legacy)
        )
        assert worst < 1e-4, f"engine/legacy divergence {worst:.2e}"

    speedup = t_leg.seconds / t_eng.seconds
    evals = n_plans * n_tokens
    emit("engine/legacy_sweep", t_leg.seconds / evals * 1e6,
         f"plans_per_s={n_plans / t_leg.seconds:.2f};"
         f"tokens_per_s={evals / t_leg.seconds:.0f}")
    emit("engine/jit_sweep_cold", t_eng.seconds / evals * 1e6,
         f"plans_per_s={n_plans / t_eng.seconds:.2f};"
         f"tokens_per_s={evals / t_eng.seconds:.0f};"
         f"speedup={speedup:.1f}x")
    emit("engine/jit_sweep_hot", t_hot.seconds / evals * 1e6,
         f"plans_per_s={n_plans / t_hot.seconds:.2f};"
         f"tokens_per_s={evals / t_hot.seconds:.0f};"
         f"speedup={t_leg.seconds / t_hot.seconds:.1f}x")
    return speedup


if __name__ == "__main__":
    run()
