"""Paper Sec. VI-B / Table I: multi-expert propagation-computing trade-off.

Sweeps experts-per-satellite (N_E) x onboard parallelism (eta) for the
slotted (concentrate) vs spread placements; the crossover the paper
predicts — concentrate when propagation-limited, spread when
compute-limited — is the derived output.
"""
from __future__ import annotations

import numpy as np

from repro.core import (ComputeConfig, multi_expert_plan,
                        simulate_token_generation)

from .common import Timer, emit, paper_world


def run(n_tokens: int = 250) -> dict:
    con, topo, activ, wl, _ = paper_world(seed=0, n_slots=60)
    out: dict = {}
    # Table I platforms: RAD5545 (3.7 GFLOPS), SBC-2A72 (10.4), iX10 (fast)
    platforms = {
        "RAD5545": ComputeConfig(peak_gflops=3.7, utilization=0.7),
        "SBC-2A72": ComputeConfig(peak_gflops=10.4, utilization=0.7),
        "iX10": ComputeConfig(peak_gflops=1000.0, utilization=0.7),
    }
    for pname, comp in platforms.items():
        for n_e in (2, 4):
            res = {}
            for mode in ("slotted", "spread"):
                plan = multi_expert_plan(con, topo, activ, n_e, mode)
                with Timer() as t:
                    r = simulate_token_generation(
                        plan, topo, activ, wl, comp,
                        np.random.default_rng(5), n_tokens=n_tokens, eta=1.0)
                res[mode] = r.mean_s
            better = min(res, key=res.get)
            emit(f"multi_expert/{pname}/N_E={n_e}",
                 t.seconds * 1e6 / n_tokens,
                 f"slotted_s={res['slotted']:.4f};spread_s={res['spread']:.4f};"
                 f"better={better}")
            out[(pname, n_e)] = res
    return out


if __name__ == "__main__":
    run()
