"""Benchmark entrypoint — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table2 fig7
    PYTHONPATH=src python -m benchmarks.run --fast     # CI-sized
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: engine table2 fig6 fig7 kernels placement "
                         "multi_expert linkstate roofline")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from . import (bench_engine, bench_fig6, bench_fig7, bench_kernels,
                   bench_linkstate, bench_multi_expert, bench_placement,
                   bench_roofline, bench_table2)

    n_tok = 120 if args.fast else 400
    suite = {
        "engine": lambda: bench_engine.run(
            n_tokens=200 if args.fast else 1000,
            n_plans=8 if args.fast else 16,
            n_slots=40 if args.fast else None),
        "table2": lambda: bench_table2.run(
            n_tokens=n_tok, n_slots=60 if args.fast else None),
        "fig6": lambda: bench_fig6.run(n_tokens=150 if args.fast else 600),
        "fig7": lambda: bench_fig7.run(n_tokens=80 if args.fast else 250),
        "multi_expert": lambda: bench_multi_expert.run(
            n_tokens=80 if args.fast else 250),
        "placement": bench_placement.run,
        "kernels": bench_kernels.run,
        "linkstate": lambda: bench_linkstate.run(
            n_tokens=80 if args.fast else 250),
        "roofline": bench_roofline.run,
    }
    selected = args.only or list(suite)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in selected:
        if name not in suite:
            print(f"unknown bench {name!r}", file=sys.stderr)
            raise SystemExit(2)
        suite[name]()
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
