"""Benchmark entrypoint — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (human-readable tables are
prefixed with ``#``).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table2 fig7
    PYTHONPATH=src python -m benchmarks.run --only engine,traffic --fast
    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --fast --only traffic \
        --json-out BENCH_traffic.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _peak_rss_mb() -> float:
    """Peak resident set size of this process, MB (0.0 if unavailable)."""
    try:
        import resource
        rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # Linux reports kilobytes, macOS bytes.
        if sys.platform == "darwin":                  # pragma: no cover
            rss_kb /= 1024.0
        return round(rss_kb / 1024.0, 1)
    except Exception:                                 # pragma: no cover
        return 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark names (space- or "
                         "comma-separated); see --list")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark names and exit")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write structured results (benches that return "
                         "dicts) to this JSON file")
    ap.add_argument("--profile", action="store_true",
                    help="adds the first-call jit-compile time column to "
                         "the always-recorded per-bench wall time and "
                         "peak RSS (stdout and the --json-out payload "
                         "under '_profile')")
    args = ap.parse_args()

    compile_s = {"total": 0.0}
    if args.profile:
        # Sum jax's own compile-event durations (trace + lowering +
        # backend compile); the per-bench delta is the first-call
        # compilation cost that steady-state reruns would not pay.
        try:
            import jax

            def _on_event(key: str, value: float, **kw) -> None:
                if key.startswith("/jax/core/compile"):
                    compile_s["total"] += value

            jax.monitoring.register_event_duration_secs_listener(_on_event)
        except Exception as e:                      # pragma: no cover
            print(f"# profile: no jax compile events ({e})",
                  file=sys.stderr)

    from . import (bench_admission, bench_batching, bench_calibration,
                   bench_ctrl, bench_engine, bench_federation, bench_fig6,
                   bench_fig7, bench_fleet, bench_kernels, bench_linkstate,
                   bench_multi_expert, bench_obs, bench_placement,
                   bench_replan, bench_roofline, bench_table2,
                   bench_traffic)

    n_tok = 120 if args.fast else 400
    suite = {
        "engine": (bench_engine, lambda: bench_engine.run(
            n_tokens=200 if args.fast else 1000,
            n_plans=8 if args.fast else 16,
            n_slots=40 if args.fast else None)),
        "traffic": (bench_traffic,
                    lambda: bench_traffic.run(fast=args.fast)),
        "admission": (bench_admission,
                      lambda: bench_admission.run(fast=args.fast)),
        "batching": (bench_batching,
                     lambda: bench_batching.run(fast=args.fast)),
        "replan": (bench_replan,
                   lambda: bench_replan.run(fast=args.fast)),
        "ctrl": (bench_ctrl,
                 lambda: bench_ctrl.run(fast=args.fast)),
        "fleet": (bench_fleet,
                  lambda: bench_fleet.run(fast=args.fast)),
        "federation": (bench_federation,
                       lambda: bench_federation.run(fast=args.fast)),
        "table2": (bench_table2, lambda: bench_table2.run(
            n_tokens=n_tok, n_slots=60 if args.fast else None)),
        "fig6": (bench_fig6,
                 lambda: bench_fig6.run(n_tokens=150 if args.fast else 600)),
        "fig7": (bench_fig7,
                 lambda: bench_fig7.run(n_tokens=80 if args.fast else 250)),
        "multi_expert": (bench_multi_expert, lambda: bench_multi_expert.run(
            n_tokens=80 if args.fast else 250)),
        "placement": (bench_placement, bench_placement.run),
        "kernels": (bench_kernels, bench_kernels.run),
        "linkstate": (bench_linkstate, lambda: bench_linkstate.run(
            n_tokens=80 if args.fast else 250)),
        "roofline": (bench_roofline, bench_roofline.run),
        "calibration": (bench_calibration,
                        lambda: bench_calibration.run(fast=args.fast)),
        "obs": (bench_obs, lambda: bench_obs.run(fast=args.fast)),
    }
    if args.list:
        # One line per bench: name + the module docstring's summary line.
        width = max(len(n) for n in suite)
        for name, (module, _) in suite.items():
            summary = (module.__doc__ or "").strip().splitlines()
            print(f"{name:<{width}}  {summary[0] if summary else ''}")
        return

    selected = []
    for item in (args.only or list(suite)):
        selected += [s for s in item.split(",") if s]
    print("name,us_per_call,derived")
    t0 = time.time()
    structured: dict = {}
    profile: dict = {}
    for name in selected:
        if name not in suite:
            print(f"unknown bench {name!r} (see --list)", file=sys.stderr)
            raise SystemExit(2)
        t_bench, c_bench = time.time(), compile_s["total"]
        result = suite[name][1]()
        # Wall time and peak RSS are recorded for every bench
        # unconditionally — a --profile run that sees no jax compile
        # events still ships a non-empty profile payload.
        wall = time.time() - t_bench
        profile[name] = {"wall_s": round(wall, 3),
                         "peak_rss_mb": _peak_rss_mb()}
        if args.profile:
            comp = compile_s["total"] - c_bench
            profile[name]["compile_s"] = round(comp, 3)
            print(f"profile/{name},{wall * 1e6:.3f},"
                  f"compile_s={comp:.3f};steady_s={wall - comp:.3f}")
        if isinstance(result, dict):
            structured[name] = result
    structured["_profile"] = profile
    print(f"# total {time.time()-t0:.1f}s")
    if args.json_out:
        # Resolved service-model provenance: jax/backend the numbers were
        # produced on plus the content hash of every calibration table
        # loaded during the run — and the per-bench profile (wall, peak
        # RSS, compile time when measured) — so CI diffs compare like
        # with like and every artifact carries its own cost record.
        from repro.core import calibration
        structured["_provenance"] = dict(calibration.provenance(),
                                         profile=profile)
        with open(args.json_out, "w") as f:
            json.dump(structured, f, indent=2)
        print(f"# wrote {args.json_out}")


if __name__ == "__main__":
    main()
