"""Substrate tests: data determinism, optimizer, checkpointing, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.core import TorusSpec, expected_dispatch_cost, plan_expert_devices
from repro.data import DataConfig, SyntheticTokens, make_batch
from repro.distributed import (migration, replan_on_failure,
                               replan_with_stragglers)
from repro.models import ModelConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm, wsd_schedule)


# ---- data ------------------------------------------------------------- #


def test_data_deterministic_and_shard_disjoint():
    d = SyntheticTokens(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    a = d.batch(3, shard=0, n_shards=2)
    b = d.batch(3, shard=0, n_shards=2)
    np.testing.assert_array_equal(a, b)           # pure function of step
    c = d.batch(3, shard=1, n_shards=2)
    assert not np.array_equal(a, c)               # shards differ
    assert a.shape == (4, 16)
    assert a.min() >= 0 and a.max() < 100


def test_make_batch_frontends():
    cfg = ModelConfig(name="a", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, d_ff=64, vocab_size=64, frontend="audio")
    d = SyntheticTokens(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    b = make_batch(cfg, d, 0)
    assert "embeds" in b and "tokens" not in b
    assert (b["labels"] >= 0).all()               # audio keeps targets
    cfg_v = ModelConfig(name="v", n_layers=2, d_model=32, n_heads=4,
                        n_kv_heads=4, d_ff=64, vocab_size=64,
                        frontend="vision")
    bv = make_batch(cfg_v, d, 0)
    assert "embeds" in bv and "tokens" in bv
    n_emb = bv["embeds"].shape[1]
    assert (bv["labels"][:, :n_emb] == -1).all()


# ---- optimizer -------------------------------------------------------- #


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    state = adamw_init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = adamw_update(cfg, params, grads, state, 1.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert np.isfinite(float(gnorm))


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw_init(params)
    big = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, state2, gnorm = adamw_update(cfg, params, big, state, 1.0)
    assert float(gnorm) > 1e5
    assert float(jnp.abs(state2["mu"]["w"]).max()) <= 0.2  # clipped to norm 1


def test_schedules():
    cos = cosine_schedule(10, 100)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-6
    assert float(cos(100)) <= 0.11
    wsd = wsd_schedule(10, 100, decay_frac=0.2)
    assert abs(float(wsd(50)) - 1.0) < 1e-6       # stable plateau
    assert float(wsd(99)) < 0.05                  # decayed
    assert float(wsd(5)) == 0.5                   # warmup


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((3,))}
    assert abs(float(global_norm(t)) - np.sqrt(7)) < 1e-6


# ---- checkpointing ---------------------------------------------------- #


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "step": jnp.zeros(())}
    for s in [10, 20, 30]:
        tree = {"w": tree["w"] + 1, "step": jnp.asarray(float(s))}
        mgr.save(s, tree)
    assert latest_step(str(tmp_path)) == 30
    # retention dropped step 10
    assert not os.path.exists(tmp_path / "step_10.npz")
    step, restored = mgr.restore_latest(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_checkpoint_crash_safety(tmp_path):
    """A torn tmp file never corrupts the manifest-listed checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones(4)}
    mgr.save(1, tree)
    # simulate crash mid-write: stray tmp file
    with open(tmp_path / "step_2.npz.tmp", "w") as f:
        f.write("garbage")
    assert latest_step(str(tmp_path)) == 1
    _, restored = mgr.restore_latest(tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), 1.0)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(4)})
    with pytest.raises(ValueError):
        mgr.restore_latest({"w": jnp.ones(5)})


# ---- elastic / fault tolerance ---------------------------------------- #


def test_replan_on_failure_covers_all_experts():
    rng = np.random.default_rng(0)
    w = rng.gamma(2, 1, 16) + 0.1
    torus = TorusSpec(shape=(4, 4))
    plan0 = plan_expert_devices(w, 2, torus)
    plan1, survivors = replan_on_failure(w, 2, torus, failed_devices={3, 7})
    assert len(survivors) == 14
    # every expert is hosted exactly once; the remaining slots are empty
    occupied = plan1.expert_perm[plan1.expert_perm >= 0]
    assert sorted(occupied.tolist()) == list(range(16))
    assert plan1.n_experts == 16
    assert plan1.experts_per_device == 2          # ceil(16/14)
    mig = migration(plan0, plan1, bytes_per_expert=1e6, new_devices=survivors)
    assert 0 < len(mig.moved_experts) <= 16
    assert not set(mig.new_devices) & {3, 7}


def test_straggler_replan_drains_hot_experts():
    rng = np.random.default_rng(1)
    w = np.sort(rng.gamma(2, 1, 16))[::-1] + 0.1   # expert 0 hottest
    torus = TorusSpec(shape=(4, 4))
    base = plan_expert_devices(w, 2, torus)
    hot_dev = base.device_of_expert(0)
    plan = replan_with_stragglers(w, 2, torus, {hot_dev: 100.0})
    assert plan.device_of_expert(0) != hot_dev
    # objective under inflated costs should not get worse vs keeping base
    assert expected_dispatch_cost(plan, w, 2) <= \
        expected_dispatch_cost(base, w, 2) * 100
