"""Docs-layer tests: the CI docs job's checks must pass from pytest too
(markdown links resolve, README quickstart snippet is in sync and
executes), and the benchmark registry must expose descriptions."""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_docs_pages_exist_and_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/paper_map.md"):
        assert (REPO / page).exists(), page
        assert page in readme, f"README does not link {page}"


def test_check_docs_links_and_snippet_parity():
    """Link check + README/example snippet parity (no execution — the
    full quickstart run happens in test_quickstart_executes)."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), "--no-exec"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stderr + proc.stdout


def test_quickstart_executes():
    """The README quickstart (examples/readme_quickstart.py) runs and
    prints the ranked plan table."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "readme_quickstart.py")],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "SpaceMoE" in proc.stdout


def test_bench_list_prints_descriptions():
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stderr
    lines = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    names = {ln.split()[0] for ln in lines}
    assert {"engine", "traffic", "admission"} <= names
    for ln in lines:
        name, _, desc = ln.partition(" ")
        assert desc.strip(), f"bench {name!r} listed without a description"
