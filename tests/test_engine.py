"""Engine tests: legacy-vs-jit parity on fixed seeds, batched sweeps,
multi-expert contention, route-staleness penalties, drop accounting for
unreachable satellites, and the on-device conditional-Poisson sampler."""
import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        PlanBatch, activation_probs, baseline_plans,
                        evaluate_plans, multi_expert_plan, rand_intra_cg_plan,
                        rand_intra_plan, rand_place_plan, rank_plans,
                        sample_topk_jax, sample_topology,
                        simulate_token_generation,
                        simulate_token_generation_legacy, spacemoe_plan,
                        subnet_routing_sets)

CFG = ConstellationConfig.scaled(8, 12, n_slots=10)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    return con, topo, activ


def _parity(r_ref, r_eng, rtol=1e-5):
    """Same drops, same latencies to float32 tolerance, same stats."""
    np.testing.assert_array_equal(r_ref.delivered, r_eng.delivered)
    np.testing.assert_allclose(r_eng.token_latency_s, r_ref.token_latency_s,
                               rtol=rtol)
    np.testing.assert_allclose(r_eng.layer_latency_s, r_ref.layer_latency_s,
                               rtol=rtol)
    assert abs(r_eng.mean_s - r_ref.mean_s) / r_ref.mean_s < rtol
    assert abs(r_eng.p99_s - r_ref.p99_s) / r_ref.p99_s < rtol
    assert r_eng.drop_rate == r_ref.drop_rate


# --------------------------------------------------------------------- #
# Golden-value parity (fixed seeds, identical random streams)
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("maker_seed", [2, 3])
def test_engine_matches_legacy_all_plan_kinds(maker_seed):
    con, topo, activ = _world()
    plans = [
        spacemoe_plan(con, topo, activ),
        rand_place_plan(CFG, 4, 4, np.random.default_rng(maker_seed)),
        rand_intra_plan(CFG, 4, 4, np.random.default_rng(maker_seed)),
        rand_intra_cg_plan(CFG, 4, 4, np.random.default_rng(maker_seed)),
    ]
    for plan in plans:
        ref = simulate_token_generation_legacy(
            plan, topo, activ, WL, COMP, np.random.default_rng(5), 300)
        eng = simulate_token_generation(
            plan, topo, activ, WL, COMP, np.random.default_rng(5), 300)
        assert eng.plan_name == ref.plan_name
        _parity(ref, eng)


def test_wrapper_backend_dispatch():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    ref = simulate_token_generation(plan, topo, activ, WL, COMP,
                                    np.random.default_rng(0), 50,
                                    backend="numpy")
    assert ref.layer_latency_s.shape == (50, 4)
    with pytest.raises(ValueError):
        simulate_token_generation(plan, topo, activ, WL, COMP,
                                  np.random.default_rng(0), 50,
                                  backend="pallas")


def test_engine_matches_legacy_no_lm_head_and_node_sets():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    sets = subnet_routing_sets(CFG, 4)
    ref = simulate_token_generation_legacy(
        plan, topo, activ, WL, COMP, np.random.default_rng(11), 200,
        include_lm_head=False, node_sets=sets)
    eng = simulate_token_generation(
        plan, topo, activ, WL, COMP, np.random.default_rng(11), 200,
        include_lm_head=False, node_sets=sets)
    _parity(ref, eng)


# --------------------------------------------------------------------- #
# Batched sweeps
# --------------------------------------------------------------------- #


def test_batched_sweep_matches_per_plan_calls():
    con, topo, activ = _world()
    plans = [
        spacemoe_plan(con, topo, activ),
        rand_intra_cg_plan(CFG, 4, 4, np.random.default_rng(7)),
        multi_expert_plan(con, topo, activ, 2, "slotted"),
    ]
    batched = evaluate_plans(plans, topo, activ, WL, COMP,
                             np.random.default_rng(5), n_tokens=200)
    for plan, res in zip(plans, batched):
        solo = evaluate_plans([plan], topo, activ, WL, COMP,
                              np.random.default_rng(5), n_tokens=200)[0]
        np.testing.assert_allclose(res.token_latency_s, solo.token_latency_s,
                                   rtol=1e-6)


def test_plan_batch_dedupes_shared_gateways():
    con, topo, activ = _world()
    plans = [spacemoe_plan(con, topo, activ)] + [
        rand_intra_cg_plan(CFG, 4, 4, np.random.default_rng(s))
        for s in range(3)
    ]
    batch = PlanBatch.from_plans(plans, topo)
    # All four plans share the 4 central gateways -> 4 unique table rows.
    assert batch.dist.shape == (topo.n_slots, 4, CFG.n_sats)
    assert (batch.g_idx == np.arange(4)[None, :]).all()
    assert batch.eta.tolist() == [1.0] * 4


def test_prebuilt_batch_rejects_different_sweep():
    """Stale-batch reuse must fail loudly: same-length (even same-name)
    sweeps with different placements, node_sets, or eta are rejected."""
    con, topo, activ = _world()
    p_a = rand_intra_cg_plan(CFG, 4, 4, np.random.default_rng(0))
    p_b = rand_intra_cg_plan(CFG, 4, 4, np.random.default_rng(1))
    assert p_a.name == p_b.name     # names alone cannot distinguish them
    batch = PlanBatch.from_plans([p_a], topo)
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError):
        evaluate_plans([p_b], topo, activ, WL, COMP, rng, 50, batch=batch)
    with pytest.raises(ValueError):
        evaluate_plans([p_a], topo, activ, WL, COMP, rng, 50, batch=batch,
                       node_sets=subnet_routing_sets(CFG, 4))
    mp = multi_expert_plan(con, topo, activ, 2, "slotted")
    mbatch = PlanBatch.from_plans([mp], topo, eta=1.0)
    with pytest.raises(ValueError):
        evaluate_plans([mp], topo, activ, WL, COMP, rng, 50, batch=mbatch,
                       eta=2.0)
    # resampled topology: stale Dijkstra rows must not be served silently
    topo_b = sample_topology(con, LinkConfig(), np.random.default_rng(99))
    with pytest.raises(ValueError):
        evaluate_plans([p_a], topo_b, activ, WL, COMP, rng, 50, batch=batch)
    # the matching sweep still runs
    out = evaluate_plans([p_a], topo, activ, WL, COMP, rng, 50, batch=batch)
    assert len(out) == 1


def test_plan_batch_rejects_mixed_depth_and_empty():
    con, topo, activ = _world()
    p4 = spacemoe_plan(con, topo, activ)
    activ2 = ActivationModel.zipf(2, 4, 2, seed=1)
    p2 = spacemoe_plan(con, topo, activ2)
    with pytest.raises(ValueError):
        PlanBatch.from_plans([p4, p2], topo)
    with pytest.raises(ValueError):
        PlanBatch.from_plans([], topo)


def test_rank_plans_orders_spacemoe_first():
    con, topo, activ = _world()
    rng = np.random.default_rng(3)
    plans = baseline_plans(con, topo, activ, rng, n_random_draws=2)
    assert len(plans) == 7
    assert len({p.name for p in plans}) == 7
    ranked = rank_plans(plans, topo, activ, WL, COMP,
                        np.random.default_rng(5), n_tokens=300)
    keys = [(r.drop_rate, r.mean_s) for _, r in ranked]
    assert keys == sorted(keys)     # delivery first, then speed
    # Theorem-1 placement beats every random baseline in the sweep.
    assert ranked[0][0].name == "SpaceMoE"


# --------------------------------------------------------------------- #
# Multi-expert contention (Eq. 43)
# --------------------------------------------------------------------- #


def test_multi_expert_contention_parity_and_effect():
    con, topo, activ = _world()
    slow = ComputeConfig(peak_gflops=0.5)
    for mode in ["slotted", "spread"]:
        mp = multi_expert_plan(con, topo, activ, 2, mode)
        ref = simulate_token_generation_legacy(
            mp, topo, activ, WL, slow, np.random.default_rng(7), 300, eta=1.0)
        eng = simulate_token_generation(
            mp, topo, activ, WL, slow, np.random.default_rng(7), 300, eta=1.0)
        _parity(ref, eng)
    # Contention bites: halving eta on a stacked plan raises latency.
    mp = multi_expert_plan(con, topo, activ, 2, "slotted")
    fast_eta = evaluate_plans([mp], topo, activ, WL, slow,
                              np.random.default_rng(7), 300, eta=2.0)[0]
    slow_eta = evaluate_plans([mp], topo, activ, WL, slow,
                              np.random.default_rng(7), 300, eta=1.0)[0]
    assert slow_eta.mean_s > fast_eta.mean_s


# --------------------------------------------------------------------- #
# Route staleness (Sec. VIII extension)
# --------------------------------------------------------------------- #


def test_staleness_parity_and_monotonicity():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    ref = simulate_token_generation_legacy(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 300,
        route_staleness=3, reroute_penalty_s=0.03)
    eng = simulate_token_generation(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 300,
        route_staleness=3, reroute_penalty_s=0.03)
    _parity(ref, eng)
    fresh = simulate_token_generation(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 300,
        route_staleness=0, reroute_penalty_s=0.03)
    base = simulate_token_generation(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 300)
    # staleness=0 never pays the penalty; staleness>0 can only hurt.
    assert fresh.mean_s == base.mean_s
    assert eng.mean_s >= base.mean_s - 1e-12


# --------------------------------------------------------------------- #
# Drop accounting for unreachable satellites
# --------------------------------------------------------------------- #


def test_unreachable_satellite_counts_as_drop_not_inf():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    # Sever every ISL of the satellite hosting the hottest expert of layer
    # 0 in half the slots: tokens routed there in those slots drop.
    victim = int(plan.expert_sats[0][np.argmax(activ.probs(0))])
    touches = (topo.edges == victim).any(axis=1)
    topo.edge_mask[: topo.n_slots // 2, touches] = False
    ref = simulate_token_generation_legacy(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 400)
    eng = simulate_token_generation(
        plan, topo, activ, WL, COMP, np.random.default_rng(5), 400)
    assert eng.drop_rate > 0
    assert eng.drop_rate == ref.drop_rate
    np.testing.assert_array_equal(ref.delivered, eng.delivered)
    # Delivered tokens have finite latency; dropped ones are NaN, not inf.
    assert np.isfinite(eng.token_latency_s[eng.delivered]).all()
    assert np.isnan(eng.token_latency_s[~eng.delivered]).all()
    assert np.isfinite(eng.mean_s) and np.isfinite(eng.p99_s)


# --------------------------------------------------------------------- #
# On-device conditional-Poisson sampler
# --------------------------------------------------------------------- #


def test_sample_topk_jax_marginals_match_eq14():
    import jax

    w = np.array([4.0, 2.0, 1.0, 0.5, 0.25])
    k = 2
    draws = np.asarray(sample_topk_jax(w.astype(np.float32), k,
                                       jax.random.PRNGKey(0), 20000))
    assert draws.shape == (20000, k)
    # valid subsets: K distinct experts per draw
    assert (np.diff(np.sort(draws, axis=1), axis=1) != 0).all()
    freq = np.bincount(draws.ravel(), minlength=len(w)) / draws.shape[0]
    np.testing.assert_allclose(freq, activation_probs(w, k), atol=0.02)
    assert abs(freq.sum() - k) < 1e-9


def test_jax_sample_backend_agrees_statistically():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    host = evaluate_plans([plan], topo, activ, WL, COMP,
                          np.random.default_rng(5), n_tokens=2000)[0]
    dev = evaluate_plans([plan], topo, activ, WL, COMP,
                         np.random.default_rng(5), n_tokens=2000,
                         sample_backend="jax")[0]
    assert abs(dev.mean_s - host.mean_s) / host.mean_s < 0.05
    with pytest.raises(ValueError):
        evaluate_plans([plan], topo, activ, WL, COMP,
                       np.random.default_rng(5), sample_backend="torch")
