"""Adaptive admission control tests: slack-target parity with the
uncontrolled simulator, target-holding + goodput dominance over the
static KV cap under the regional-hotspot overload (frontier written to
BENCH_admission.json), ranked ground visibility / gateway-retry tables,
controller plumbing through the scenario registry, and config
validation."""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FleetSim, QueueConfig,
                           build_ground_segment, control_bin_flags,
                           get_scenario, resolve_admission, run_scenario,
                           sample_requests)

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    ground = build_ground_segment(con, LinkConfig(), min_elevation_deg=10.0)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, n_layers, n_experts,
                                np.random.default_rng(7))]
    return con, topo, activ, ground, plans


def _sim(plans, topo, activ, ground, req, qcfg, seed=5):
    return FleetSim(plans, topo, activ, WL, COMP, req,
                    np.random.default_rng(seed), qcfg=qcfg, ground=ground)


# --------------------------------------------------------------------- #
# (a) slack target == uncontrolled
# --------------------------------------------------------------------- #


def test_slack_target_reproduces_uncontrolled_steady_state():
    """With a latency target far above anything the trace can reach, the
    controller must admit everything at attempt 0 (zero shedding, zero
    retries) and reproduce the uncontrolled metrics bit-for-bit."""
    con, topo, activ, ground, plans = _world()
    rng = np.random.default_rng(3)
    req = sample_requests(rng, rate_rps=1.0, horizon_s=40.0,
                          n_stations=ground.n_stations,
                          prompt_median=4, prompt_max=16,
                          decode_mean=4, decode_max=8)
    base = _sim(plans, topo, activ, ground, req,
                QueueConfig(dt_s=0.05, tail_s=30.0)).run()
    slack = AdmissionConfig(ttft_target_s=1e6)
    ctrl = _sim(plans, topo, activ, ground, req,
                QueueConfig(dt_s=0.05, tail_s=30.0, admission=slack)).run()
    for p in range(len(plans)):
        b, c = base.plans[p], ctrl.plans[p]
        assert c.shed_rate == 0.0
        assert (c.retries == 0).all()
        np.testing.assert_array_equal(b.served, c.served)
        np.testing.assert_array_equal(b.ttft_s, c.ttft_s)
        np.testing.assert_array_equal(b.e2e_s, c.e2e_s)
        assert b.goodput_tok_s == c.goodput_tok_s


# --------------------------------------------------------------------- #
# (b) hotspot overload: hold the target, beat the static cap
# --------------------------------------------------------------------- #


def test_controller_holds_target_and_beats_static_cap():
    """Under the regional-hotspot overload the AIMD controller keeps the
    served p99 TTFT within the target while delivering at least the
    static-cap baseline's goodput; the measured frontier is written to
    BENCH_admission.json."""
    con, topo, activ, ground, plans = _world()
    sc = get_scenario("regional-hotspot")
    sc = dataclasses.replace(sc, horizon_s=60.0, tail_s=60.0)
    req = sc.requests(np.random.default_rng(2), ground.n_stations,
                      rate_scale=6.0)
    assert req.n_requests > 50                     # genuinely overloaded

    static = _sim(plans, topo, activ, ground, req,
                  QueueConfig(dt_s=0.05, tail_s=60.0, kv_slots=8)).run()
    zero = _sim(plans, topo, activ, ground, req,
                QueueConfig(dt_s=0.05, tail_s=60.0)).run(zero_load=True)
    target = 3.0 * max(p.quantile("ttft", 0.99) for p in zero.plans)

    frontier = [dict(policy="static", knob=8.0, **{
        "plan": p.plan_name, "goodput_tok_s": p.goodput_tok_s,
        "ttft_p99_s": p.quantile("ttft", 0.99),
        "shed_rate": p.shed_rate, "drop_rate": p.drop_rate,
    }) for p in static.plans]
    for scale in (3.0, 5.0):
        t = scale / 3.0 * target
        acfg = AdmissionConfig(ttft_target_s=t)
        ctrl = _sim(plans, topo, activ, ground, req,
                    QueueConfig(dt_s=0.05, tail_s=60.0,
                                admission=acfg)).run()
        for p, s in zip(ctrl.plans, static.plans):
            assert p.shed_rate > 0.0               # overload: load was shed
            assert p.quantile("ttft", 0.99) <= t   # target held
            assert p.goodput_tok_s >= s.goodput_tok_s   # >= static cap
            frontier.append(dict(
                policy="aimd", knob=t, plan=p.plan_name,
                goodput_tok_s=p.goodput_tok_s,
                ttft_p99_s=p.quantile("ttft", 0.99),
                shed_rate=p.shed_rate, drop_rate=p.drop_rate))
    out = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_admission.json"
    out.write_text(json.dumps(
        {"world": "test-8x12", "offered_rps": req.n_requests / 60.0,
         "frontier": frontier}, indent=2))
    assert out.exists() and len(frontier) >= 6


def test_retry_recovers_goodput_over_no_retry():
    """Gateway retry should never lose requests relative to the same
    controller with retries disabled (the retried fraction is extra
    admitted mass)."""
    con, topo, activ, ground, plans = _world()
    sc = get_scenario("regional-hotspot")
    req = dataclasses.replace(sc, horizon_s=40.0).requests(
        np.random.default_rng(9), ground.n_stations, rate_scale=5.0)
    kw = dict(ttft_target_s=15.0)
    with_retry = _sim(plans, topo, activ, ground, req,
                      QueueConfig(dt_s=0.05, tail_s=40.0,
                                  admission=AdmissionConfig(**kw))).run()
    no_retry = _sim(plans, topo, activ, ground, req,
                    QueueConfig(dt_s=0.05, tail_s=40.0,
                                admission=AdmissionConfig(
                                    max_retries=0, **kw))).run()
    for p_r, p_n in zip(with_retry.plans, no_retry.plans):
        assert p_r.shed_rate <= p_n.shed_rate + 1e-12
        if p_r.retry_rate > 0:
            # retried requests paid latency for admission: TTFT includes
            # the backoff + terrestrial forward
            retried = p_r.served & (p_r.retries > 0)
            assert p_r.ttft_s[retried].min() >= \
                AdmissionConfig(**kw).retry_backoff_s


# --------------------------------------------------------------------- #
# Ranked ground tables + retry ordering
# --------------------------------------------------------------------- #


def test_ground_ranked_visibility_table():
    con, topo, activ, ground, plans = _world()
    assert ground.n_ranked > 1
    # rank 0 is exactly the legacy argmax ingress
    np.testing.assert_array_equal(ground.ingress_ranked[..., 0],
                                  ground.ingress_sat)
    # elevations non-increasing along the rank axis (where visible)
    el = ground.elevation_ranked_rad
    vis = ground.ingress_ranked >= 0
    both = vis[..., :-1] & vis[..., 1:]
    assert (el[..., :-1][both] >= el[..., 1:][both] - 1e-12).all()
    # invisible tail is padded with -1 / +inf
    assert np.isinf(ground.uplink_ranked_s[~vis]).all()


def test_ground_retry_stations_exclude_origin_and_rank_by_latency():
    con, topo, activ, ground, plans = _world()
    rng = np.random.default_rng(0)
    R = 64
    slots = rng.integers(0, ground.n_slots, R)
    origin = rng.integers(0, ground.n_stations, R)
    alts = ground.retry_stations(slots, origin, 3)
    assert alts.shape == (R, 3)
    assert (alts != origin[:, None]).all()
    score = ground.uplink_s[slots] + ground.ground_delay_s[origin]
    picked = np.take_along_axis(score, alts, axis=1)
    assert (np.diff(picked, axis=1) >= -1e-12).all()
    # terrestrial delay table: symmetric, zero diagonal, sub-100ms
    g = ground.ground_delay_s
    np.testing.assert_allclose(g, g.T)
    assert (np.diag(g) == 0).all() and g.max() < 0.11


def test_retry_stations_never_returns_origin_under_sparse_visibility():
    """The origin's +inf score can tie with invisible gateways' +inf
    uplinks — the origin must still never appear among the retries."""
    from repro.traffic import GroundSegment, GroundStation
    stations = (GroundStation("a", 0.0, 0.0), GroundStation("b", 0.0, 90.0),
                GroundStation("c", 0.0, 180.0))
    g = GroundSegment(
        stations=stations,
        ingress_sat=np.array([[3, -1, -1]]),      # only the origin sees a sat
        uplink_s=np.array([[0.01, np.inf, np.inf]]),
        elevation_rad=np.zeros((1, 3)),
        min_elevation_deg=25.0)
    alts = g.retry_stations(np.array([0]), np.array([0]), 2)
    assert alts.shape == (1, 2)
    assert (alts != 0).all()


def test_no_ground_retries_are_same_gateway_backoff():
    """Without a ground segment a retry re-attempts the (single logical)
    gateway after the backoff — feasible wherever attempt 0 was."""
    con, topo, activ, ground, plans = _world()
    req = sample_requests(np.random.default_rng(1), rate_rps=1.0,
                          horizon_s=20.0, n_stations=1, prompt_median=4,
                          prompt_max=8, decode_mean=2, decode_max=4)
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=20.0,
                                    admission=AdmissionConfig()))
    np.testing.assert_array_equal(sim._att_feasible[1], sim._att_feasible[0])
    assert (sim._att_extra[1] >= sim._att_extra[0]
            + AdmissionConfig().retry_backoff_s - 1e-12).all()


# --------------------------------------------------------------------- #
# Scenario plumbing + config validation + kernel helpers
# --------------------------------------------------------------------- #


def test_controlled_scenarios_registered_and_runnable():
    con, topo, activ, ground, plans = _world()
    for name in ("regional-hotspot-controlled", "failure-storm-controlled"):
        sc = get_scenario(name)
        assert sc.admission is not None and sc.admission.policy == "aimd"
        assert sc.kv_slots == 0              # the controller replaces the cap
    sc = dataclasses.replace(
        get_scenario("regional-hotspot-controlled"), horizon_s=30.0,
        tail_s=30.0, decode_mean=4, decode_max=8, prompt_median=4,
        prompt_max=16)
    out = run_scenario(sc, plans, topo, activ, WL, COMP,
                       np.random.default_rng(4), ground=ground,
                       constellation=con)
    rows = out.result.table(sc.slo, scenario=sc.name)
    assert {"shed_rate", "retry_rate"} <= set(rows[0])


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(policy="nope")
    with pytest.raises(ValueError):
        AdmissionConfig(decrease=1.5)
    with pytest.raises(ValueError):
        AdmissionConfig(increase=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_retries=-1)
    with pytest.raises(ValueError):
        AdmissionConfig(target_margin=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(reference_quantile=1.5)
    assert AdmissionConfig().n_attempts == 3


def test_control_bin_flags_cadence():
    flags = control_bin_flags(10, dt_s=0.05, interval_s=0.2)  # every 4 bins
    np.testing.assert_array_equal(np.flatnonzero(flags), [3, 7])
    assert control_bin_flags(4, dt_s=0.5, interval_s=0.1).all()


def test_resolve_admission_first_feasible_attempt_wins():
    P, G, T, A, R = 2, 2, 4, 3, 3
    admit = np.ones((P, G, T))
    admit[0, 0, :] = 0.0                      # plan 0, gateway 0 rejects
    attempt_bin = np.zeros((A, R), dtype=np.int64)
    attempt_station = np.array([[0, 0, 0], [1, 1, 1], [1, 1, 1]])
    feasible = np.ones((A, P, R), dtype=bool)
    feasible[1, :, 2] = False                 # r2 must go to attempt 2
    u = np.full((A, R), 0.5)
    choice, shed = resolve_admission(admit, attempt_bin, attempt_station,
                                     feasible, u)
    assert not shed.any()
    np.testing.assert_array_equal(choice[0], [1, 1, 2])   # retried off g0
    np.testing.assert_array_equal(choice[1], [0, 0, 0])   # plan 1 admits
    # all-rejecting trace -> shed
    choice, shed = resolve_admission(np.zeros((P, G, T)), attempt_bin,
                                     attempt_station, feasible, u)
    assert shed.all()
