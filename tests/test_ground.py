"""Ground-segment edge cases: zero-visibility windows, deterministic
ranked-visibility tie-breaks, single-gateway worlds with no retry
fallback, and the federation-level constellation ranking."""
import numpy as np
import pytest

from repro.core import Constellation, ConstellationConfig, LinkConfig
from repro.traffic import (DEFAULT_STATIONS, GroundSegment, GroundStation,
                           build_ground_segment, ground_delay_table,
                           rank_constellations)

CFG = ConstellationConfig.scaled(6, 8, n_slots=6, survival_prob=1.0)


def _segment(min_elevation_deg=10.0, stations=DEFAULT_STATIONS, n_ranked=4):
    con = Constellation(CFG)
    return build_ground_segment(con, LinkConfig(), stations=stations,
                                min_elevation_deg=min_elevation_deg,
                                n_ranked=n_ranked)


# --------------------------------------------------------------------- #
# Zero-visible-gateway windows
# --------------------------------------------------------------------- #


def test_zero_visibility_window_is_consistent_across_tables():
    """An impossible elevation mask leaves every (slot, station) pair
    dark: -1 ingress, +inf uplink, floor elevation — in both the rank-0
    arrays and the full ranked tables — and coverage reads 0."""
    g = _segment(min_elevation_deg=89.99)
    assert g.coverage() == 0.0
    assert (g.ingress_sat == -1).all()
    assert np.isinf(g.uplink_s).all()
    assert (g.ingress_ranked == -1).all()
    assert np.isinf(g.uplink_ranked_s).all()
    assert (g.elevation_ranked_rad == -np.pi / 2).all()
    # Request-level lookups keep the sentinel semantics.
    sat, up = g.for_requests(np.zeros(3, dtype=int),
                             np.array([0, 1, 2]))
    assert (sat == -1).all() and np.isinf(up).all()


def test_partial_visibility_pads_ranked_tail_with_sentinels():
    """Where fewer than n_ranked satellites clear the mask, the ranked
    tail is exactly (-1, +inf) — never a stale satellite id."""
    g = _segment(min_elevation_deg=25.0)
    dark = g.ingress_ranked < 0
    assert dark.any()                       # mask actually bites somewhere
    assert np.isinf(g.uplink_ranked_s[dark]).all()
    lit = ~dark
    assert np.isfinite(g.uplink_ranked_s[lit]).all()
    # Visible prefix: once a rank is dark, every deeper rank is dark too
    # (elevations are sorted descending, so -inf entries sort last).
    assert (dark[..., :-1] <= dark[..., 1:]).all()


# --------------------------------------------------------------------- #
# Ranked-visibility determinism under ties
# --------------------------------------------------------------------- #


def test_ranked_visibility_ties_break_by_satellite_index():
    """Two gateways at the identical site see the identical sky, and a
    rebuild reproduces the tables bit-for-bit — the stable argsort
    leaves no platform-dependent tie order."""
    twin = (GroundStation("site-a", 12.0, 34.0),
            GroundStation("site-b", 12.0, 34.0))
    g1 = _segment(stations=twin)
    g2 = _segment(stations=twin)
    np.testing.assert_array_equal(g1.ingress_ranked[:, 0],
                                  g1.ingress_ranked[:, 1])
    np.testing.assert_array_equal(g1.uplink_ranked_s[:, 0],
                                  g1.uplink_ranked_s[:, 1])
    np.testing.assert_array_equal(g1.ingress_ranked, g2.ingress_ranked)
    np.testing.assert_array_equal(g1.uplink_ranked_s, g2.uplink_ranked_s)


def test_retry_stations_orders_by_forward_plus_uplink_and_drops_origin():
    g = _segment()
    R = 32
    rng = np.random.default_rng(0)
    slots = rng.integers(0, g.n_slots, R)
    origin = rng.integers(0, g.n_stations, R)
    alt = g.retry_stations(slots, origin, n_alternatives=g.n_stations - 1)
    assert alt.shape == (R, g.n_stations - 1)
    # The origin never appears; every other gateway appears exactly once.
    for r in range(R):
        assert origin[r] not in alt[r]
        assert len(set(alt[r])) == g.n_stations - 1
    # Ranking follows forward-delay + best-uplink cost (monotone score;
    # an invisible-gateway tail diffs inf - inf = NaN, which is still a
    # correctly-ordered tie).
    score = g.uplink_s[slots] + g.ground_delay_s[origin]       # (R, S)
    ranked_scores = np.take_along_axis(score, alt, axis=1)
    with np.errstate(invalid="ignore"):
        d = np.diff(ranked_scores, axis=1)
    assert ((d >= 0) | np.isnan(d)).all()


# --------------------------------------------------------------------- #
# Single-gateway worlds: retry has no fallback
# --------------------------------------------------------------------- #


def test_single_gateway_world_has_no_retry_fallback():
    """With one gateway there is no alternative to retry at: the table
    is empty at any requested depth, and the ground-delay matrix is the
    1x1 zero."""
    g = _segment(stations=(GroundStation("only", 40.0, -100.0),))
    assert g.n_stations == 1
    alt = g.retry_stations(np.zeros(5, dtype=int), np.zeros(5, dtype=int),
                           n_alternatives=3)
    assert alt.shape == (5, 0)
    assert g.ground_delay_s.shape == (1, 1)
    assert g.ground_delay_s[0, 0] == 0.0


def test_ground_delay_table_symmetric_zero_diagonal():
    d = ground_delay_table(DEFAULT_STATIONS)
    np.testing.assert_allclose(d, d.T)
    assert (np.diag(d) == 0.0).all()
    off = d[~np.eye(len(DEFAULT_STATIONS), dtype=bool)]
    assert (off > 0).all()


# --------------------------------------------------------------------- #
# Federation-level constellation ranking
# --------------------------------------------------------------------- #


def test_rank_constellations_orders_by_cost_with_index_tie_break():
    costs = np.array([
        [0.5, np.inf, 1.0, 2.0],
        [0.5, np.inf, 0.5, 1.0],
        [0.2, 3.0, 0.5, np.inf],
    ])
    ranking = rank_constellations(costs)
    assert ranking.shape == (4, 3)
    # Request 0: member 2 cheapest, then the 0.5 tie breaks 0 before 1.
    np.testing.assert_array_equal(ranking[0], [2, 0, 1])
    # Request 1: only member 2 is feasible; the +inf tail keeps index
    # order.
    np.testing.assert_array_equal(ranking[1], [2, 0, 1])
    # Request 2: 0.5 tie between members 1 and 2 breaks by index.
    np.testing.assert_array_equal(ranking[2], [1, 2, 0])
    # Request 3: infeasible member 2 sorts last.
    np.testing.assert_array_equal(ranking[3], [1, 0, 2])


def test_rank_constellations_rejects_bad_shape():
    with pytest.raises(ValueError):
        rank_constellations(np.zeros(3))
