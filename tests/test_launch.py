"""Launch-layer tests: sharding rules, input specs, small-mesh end-to-end
(multi-device runs happen in a subprocess so XLA device count can be set)."""
import json
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, smoke_config
from repro.distributed.sharding import ShardingRules
from repro.launch.roofline import model_flops
from repro.launch.steps import input_specs

# --------------------------------------------------------------------- #
# input_specs: every (arch x shape) cell has well-defined structs
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "llava-next-mistral-7b",
                                  "musicgen-medium", "xlstm-350m"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_structures(arch, shape):
    cfg = get_config(arch)
    spec = SHAPES[shape]
    s = input_specs(cfg, spec)
    assert "params" in s
    if spec.kind == "train":
        assert "opt_state" in s and "batch" in s
        assert s["batch"]["labels"].shape == (spec.global_batch, spec.seq_len)
    else:
        assert "cache" in s and "pos" in s
        if cfg.frontend == "audio":
            assert s["tokens"] is None and "embeds" in s
        else:
            assert s["tokens"].shape == (spec.global_batch, 1)
    # nothing was allocated
    flat = [x for x in jax.tree.leaves(s) if x is not None]
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in flat)


def test_model_flops_magnitudes():
    cfg = get_config("mistral-large-123b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    # 6 * 123e9 * (256*4096) ~ 7.7e17 plus attention
    assert 7e17 < f_train < 1.2e18
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert 2 * 123e9 * 128 * 0.9 < f_dec < 2 * 123e9 * 128 * 3


# --------------------------------------------------------------------- #
# Sharding rules on a tiny mesh (1 device: specs still well-formed)
# --------------------------------------------------------------------- #


def test_sharding_rules_divisibility_guards():
    import numpy as np

    class FakeMesh:
        shape = {"data": 4, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("granite-moe-3b-a800m")          # 40 experts: not / 16
    rules = ShardingRules(cfg, FakeMesh())
    spec = rules.param_spec(
        (jax.tree_util.DictKey("units"), jax.tree_util.DictKey("b0"),
         jax.tree_util.DictKey("ffn"), jax.tree_util.DictKey("w_gate")),
        jax.ShapeDtypeStruct((31, 40, 1536, 512), jax.numpy.float32),
    )
    # EP impossible (40 % 16 != 0) -> TP on d_ff instead
    assert spec == P(None, None, None, "model")

    cfg2 = get_config("deepseek-moe-16b")             # 64 experts: / 16
    rules2 = ShardingRules(cfg2, FakeMesh())
    spec2 = rules2.param_spec(
        (jax.tree_util.DictKey("units"), jax.tree_util.DictKey("b0"),
         jax.tree_util.DictKey("ffn"), jax.tree_util.DictKey("w_gate")),
        jax.ShapeDtypeStruct((27, 64, 2048, 1408), jax.numpy.float32),
    )
    assert spec2 == P(None, "model", None, None)

    # batch=1 cache: batch unshardable -> context parallelism on seq
    cspec = rules2.cache_spec(
        (jax.tree_util.DictKey("units"), jax.tree_util.DictKey("b0"),
         jax.tree_util.DictKey("k")),
        jax.ShapeDtypeStruct((27, 1, 1024, 16, 128), jax.numpy.bfloat16),
    )
    assert cspec == P(None, None, "data", "model", None)


# --------------------------------------------------------------------- #
# Multi-device end-to-end (subprocess with forced host device count)
# --------------------------------------------------------------------- #

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.models import Parallel, init_params, loss_fn, random_batch
from repro.distributed.sharding import ShardingRules

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("deepseek-moe-16b")   # 8 experts / 4 = 2 per device
par = Parallel(mesh=mesh)
rules = ShardingRules(cfg, mesh)
params = init_params(cfg, jax.random.PRNGKey(0))
batch = random_batch(cfg, 4, 32, seed=1)

# single-shard reference
ref, _ = loss_fn(cfg, params, batch)

p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                    rules.param_specs(params),
                    is_leaf=lambda s: isinstance(s, P))
b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), rules.batch_spec(batch),
                    is_leaf=lambda s: isinstance(s, P))
params_d = jax.device_put(params, p_sh)
batch_d = jax.device_put({k: jnp.asarray(v) for k, v in batch.items()}, b_sh)
with mesh:
    dist, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, par=par))(params_d, batch_d)
print(json.dumps({"ref": float(ref), "dist": float(dist)}))
"""


@pytest.mark.slow
def test_distributed_loss_matches_single_shard():
    """EP shard_map path on 8 host devices == local math (same routing)."""
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        timeout=600, cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert abs(out["ref"] - out["dist"]) / abs(out["ref"]) < 2e-2, out


@pytest.mark.slow
def test_dryrun_cli_end_to_end(tmp_path):
    """The actual deliverable path: dryrun CLI lowers+compiles a cell on the
    512-device production mesh and emits a roofline JSON artifact."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--mesh", "pod", "--out", str(tmp_path),
         "--force"],
        capture_output=True, text=True, timeout=900, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(
        (tmp_path / "smollm-135m__decode_32k__pod_16x16.json").read_text()
    )
    assert out["status"] == "ok"
    assert out["n_devices"] == 256
    r = out["roofline"]
    assert r["memory_s"] > 0 and r["dominant"] in (
        "compute", "memory", "collective")
    assert out["memory_analysis"]["argument_size_in_bytes"] > 0
