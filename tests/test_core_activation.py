"""Unit tests: ESP DP, activation probabilities, conditional-Poisson sampler."""
import itertools

import numpy as np
import pytest

from repro.core import (ActivationModel, activation_probs,
                        activation_probs_jax, esp, esp_jax,
                        esp_prefix_table, sample_topk, subset_pmf)


def brute_esp(w, k):
    return sum(
        np.prod([w[i] for i in comb])
        for comb in itertools.combinations(range(len(w)), k)
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,k", [(5, 2), (8, 3), (12, 6)])
def test_esp_matches_enumeration(seed, n, k):
    rng = np.random.default_rng(seed)
    w = rng.gamma(2.0, 1.0, size=n) + 1e-3
    e = esp(w, k)
    for j in range(k + 1):
        assert np.isclose(e[j], brute_esp(w, j), rtol=1e-10)


def test_esp_prefix_table_consistency():
    rng = np.random.default_rng(3)
    w = rng.gamma(2.0, 1.0, size=10) + 1e-3
    t = esp_prefix_table(w, 4)
    for i in range(11):
        np.testing.assert_allclose(t[i], esp(w[:i], 4), rtol=1e-10)


def test_esp_extreme_scales():
    # scaling invariance: e_k(c*w) = c^k e_k(w)
    w = np.array([1e-8, 2e-8, 3e-8, 5e-8])
    e_small = esp(w, 2)
    e_big = esp(w * 1e12, 2)
    np.testing.assert_allclose(e_big[2], e_small[2] * 1e24, rtol=1e-10)


@pytest.mark.parametrize("n,k", [(4, 1), (8, 2), (64, 6), (40, 8)])
def test_activation_probs_sum_to_k(n, k):
    rng = np.random.default_rng(7)
    w = rng.gamma(1.0, 1.0, size=n) + 1e-3
    p = activation_probs(w, k)
    assert np.all(p > 0) and np.all(p < 1 + 1e-12)
    assert np.isclose(p.sum(), k, rtol=1e-9)


def test_activation_probs_monotone_in_weight():
    w = np.array([0.5, 1.0, 2.0, 4.0, 8.0])
    p = activation_probs(w, 2)
    assert np.all(np.diff(p) > 0)  # Eq. 14: P_i increasing in w_i


def test_activation_probs_direct_formula():
    # P_i = sum over subsets containing i of Eq. 12 PMF
    rng = np.random.default_rng(11)
    w = rng.gamma(2.0, 1.0, size=6) + 1e-2
    pmf = subset_pmf(w, 3)
    p = activation_probs(w, 3)
    for i in range(6):
        direct = sum(v for u, v in pmf.items() if i in u)
        assert np.isclose(p[i], direct, rtol=1e-10)


def test_jax_paths_match_numpy():
    rng = np.random.default_rng(5)
    w = rng.gamma(2.0, 1.0, size=16) + 1e-2
    np.testing.assert_allclose(np.asarray(esp_jax(w, 4)), esp(w, 4), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(activation_probs_jax(w, 4)), activation_probs(w, 4), rtol=1e-5
    )


def test_sampler_matches_pmf():
    """Empirical subset frequencies vs Eq. 12 (exact sequential sampler)."""
    rng = np.random.default_rng(42)
    w = np.array([4.0, 2.0, 1.0, 0.5, 0.25])
    k = 2
    n_draws = 40000
    draws = sample_topk(w, k, rng, n_draws)
    assert draws.shape == (n_draws, k)
    # each row: k distinct indices
    assert all(len(set(row)) == k for row in draws[:100])
    pmf = subset_pmf(w, k)
    counts: dict = {}
    for row in draws:
        key = tuple(sorted(row))
        counts[key] = counts.get(key, 0) + 1
    for u, p in pmf.items():
        emp = counts.get(u, 0) / n_draws
        se = np.sqrt(p * (1 - p) / n_draws)
        assert abs(emp - p) < 6 * se + 1e-4, (u, emp, p)


def test_sampler_marginals_match_eq14():
    rng = np.random.default_rng(9)
    w = np.array([8.0, 4.0, 2.0, 1.0, 1.0, 0.5, 0.25, 0.125])
    k = 3
    draws = sample_topk(w, k, rng, 30000)
    emp = np.bincount(draws.ravel(), minlength=8) / 30000
    np.testing.assert_allclose(emp, activation_probs(w, k), atol=0.01)


def test_activation_model_constructors():
    m = ActivationModel.zipf(4, 8, 2, seed=0)
    assert m.all_probs().shape == (4, 8)
    assert np.allclose(m.all_probs().sum(axis=1), 2.0)
    u = ActivationModel.uniform(2, 4, 2)
    assert np.allclose(u.probs(0), 0.5)
    counts = np.random.default_rng(0).integers(1, 100, size=(3, 8))
    f = ActivationModel.from_router_counts(counts, 2)
    assert f.all_probs().shape == (3, 8)


def test_sampler_rejects_bad_k():
    with pytest.raises(ValueError):
        sample_topk(np.ones(4), 5, np.random.default_rng(0))
    with pytest.raises(ValueError):
        ActivationModel(weights=np.zeros((2, 4)), top_k=2)
