"""repro.compat: the jax version shims resolve and run on every supported
jax version (shard_map location + check kwarg, axis_size, cost_analysis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, cost_analysis, shard_map


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("x",))


def test_shard_map_runs_identity():
    f = shard_map(lambda a: a * 2, _mesh(), in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(f(jnp.arange(4)), 2 * jnp.arange(4))


@pytest.mark.parametrize("kw", ["check_vma", "check_rep"])
def test_shard_map_accepts_either_check_keyword(kw):
    f = shard_map(lambda a: a + 1, _mesh(), in_specs=P(), out_specs=P(),
                  **{kw: False})
    np.testing.assert_array_equal(f(jnp.zeros(3)), jnp.ones(3))


def test_shard_map_rejects_conflicting_check_flags():
    with pytest.raises(ValueError):
        shard_map(lambda a: a, _mesh(), in_specs=P(), out_specs=P(),
                  check_vma=True, check_rep=False)


def test_axis_size_static_inside_shard_map():
    def body(a):
        n = axis_size("x")
        assert isinstance(n, int)       # static: usable in reshapes
        return a * n

    f = shard_map(body, _mesh(), in_specs=P(), out_specs=P(),
                  check_vma=False)
    np.testing.assert_array_equal(f(jnp.ones(2)), jnp.ones(2))


def test_cost_analysis_returns_flat_dict():
    compiled = jax.jit(lambda a: a @ a).lower(jnp.ones((8, 8))).compile()
    cost = cost_analysis(compiled)
    assert isinstance(cost, dict)
    assert cost["flops"] > 0
