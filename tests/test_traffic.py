"""Traffic subsystem tests: M/D/1 queueing against the Pollaczek-Khinchine
closed form, exact zero-load parity with the batched engine, arrival
processes, ground-segment geometry, backpressure/KV admission drops,
scenario registry, failure-storm elastic replanning and saturation
sweeps."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        PlanBatch, evaluate_plans, ingress_offsets,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (SCENARIOS, BatchingConfig, FleetSim, QueueConfig,
                           RequestBatch, apply_failure_storm,
                           build_ground_segment, get_scenario,
                           poisson_arrivals, run_scenario, sample_requests,
                           saturation_sweep, station_waiting_times)

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2, cfg=CFG):
    con = Constellation(cfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    return con, topo, activ


def _plans(con, topo, activ, seed=7):
    return [spacemoe_plan(con, topo, activ),
            rand_intra_cg_plan(con.cfg, activ.n_layers, activ.n_experts,
                               np.random.default_rng(seed))]


def _uniform_requests(n, gap_s=50.0, prompt=1, decode=6):
    return RequestBatch(
        arrival_s=np.arange(n) * gap_s,
        prompt_len=np.full(n, prompt, dtype=np.int64),
        decode_len=np.full(n, decode, dtype=np.int64),
        station=np.zeros(n, dtype=np.int64),
    )


# --------------------------------------------------------------------- #
# Queueing correctness
# --------------------------------------------------------------------- #


def test_mdone_matches_pollaczek_khinchine():
    """Single-station M/D/1 mean wait vs the P-K closed form
    Wq = rho * s / (2 (1 - rho)), within Monte-Carlo + O(dt) tolerance."""
    lam, s = 30.0, 0.02                     # rho = 0.6
    pk = lam * s * s / (2.0 * (1.0 - lam * s))
    rng = np.random.default_rng(42)
    t = poisson_arrivals(lam, 400.0, rng)
    w = station_waiting_times(t, s, dt_s=0.002, horizon_s=450.0)
    assert abs(w.mean() - pk) / pk < 0.08
    # At rho > 1 the backlog diverges instead.
    t2 = poisson_arrivals(2.0 / s, 200.0, np.random.default_rng(1))
    w2 = station_waiting_times(t2, s, dt_s=0.002, horizon_s=250.0)
    assert w2[-100:].mean() > 10 * pk


def test_batch_arrivals_match_batch_pollaczek_khinchine():
    """Batched kernel vs the batch-arrival (M^[G]/D/1) P-K closed form.

    G simultaneous arrivals at Poisson epochs of rate lam_b, each with
    deterministic demand d, see mean wait

        E[W] = lam_b G^2 d^2 / (2 (1 - rho)) + (G - 1) d / 2,

    (batch-work M/G/1 delay plus the mean within-batch position delay,
    rho = lam_b G d).  The unbatched kernel must match the formula at
    service d; with ``BatchingConfig(b_max=G)`` every batch drains at
    the table speedup s(G), so the same formula at d -> d / s(G) must
    hold — the analytic pin on the continuous-batching service term,
    alongside the M/D/1 pin above."""
    lam_b, G, d = 6.0, 4, 0.02               # rho = 0.48 unbatched
    rng = np.random.default_rng(33)
    epochs = poisson_arrivals(lam_b, 400.0, rng)
    t = np.repeat(epochs, G)

    def pk_batch(dd):
        rho = lam_b * G * dd
        return lam_b * G * G * dd * dd / (2.0 * (1.0 - rho)) \
            + (G - 1) * dd / 2.0

    w = station_waiting_times(t, d, dt_s=0.002, horizon_s=450.0)
    assert abs(w.mean() - pk_batch(d)) / pk_batch(d) < 0.08

    speedup = (1.0, 1.6, 2.1, 2.5)
    wb = station_waiting_times(
        t, d, dt_s=0.002, horizon_s=450.0,
        batching=BatchingConfig(b_max=G, speedup=speedup))
    d_eff = d / speedup[G - 1]
    assert abs(wb.mean() - pk_batch(d_eff)) / pk_batch(d_eff) < 0.08
    assert wb.mean() < w.mean()              # batching strictly helps


def test_station_waits_zero_at_zero_load():
    w = station_waiting_times(np.array([1.0, 5.0, 9.0]), 0.001, dt_s=0.01)
    np.testing.assert_array_equal(w, 0.0)


def test_zero_load_reproduces_engine_exactly():
    """A trickle of prompt-1 requests must reproduce evaluate_plans token
    latencies bit-for-bit (waits all zero, same slots, same draws)."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    req = _uniform_requests(5)
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(9), qcfg=QueueConfig(dt_s=0.05))
    res = sim.run()
    ref = evaluate_plans(plans, topo, activ, WL, COMP,
                         np.random.default_rng(9), n_tokens=sim.n_tokens,
                         slots=res.slots)
    for p in range(len(plans)):
        assert res.plans[p].served.all()
        np.testing.assert_array_equal(res.plans[p].token_total_s,
                                      ref[p].token_latency_s)


def test_load_inflates_latency_monotonically():
    """The same trace at full rate vs heavily thinned: queue waits can
    only grow latencies, never shrink them."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    # A burst: everything arrives within a second.
    rng = np.random.default_rng(3)
    req = RequestBatch(
        arrival_s=np.sort(rng.random(40)),
        prompt_len=np.full(40, 4), decode_len=np.full(40, 5),
        station=np.zeros(40, dtype=np.int64))
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5), qcfg=QueueConfig(dt_s=0.02))
    sparse = sim.run(active=np.arange(40) == 0)
    dense = sim.run()
    p99_sparse = sparse.plans[0].quantile("e2e", 0.5)
    p99_dense = dense.plans[0].quantile("e2e", 0.5)
    assert p99_dense > p99_sparse
    # token latencies never below the zero-load base
    assert (dense.plans[0].token_total_s >= sim.tok_base[0] - 1e-12).all()


def test_buffer_overflow_drops_requests():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)[:1]
    req = RequestBatch(
        arrival_s=np.zeros(30) + np.arange(30) * 1e-3,
        prompt_len=np.full(30, 64), decode_len=np.full(30, 4),
        station=np.zeros(30, dtype=np.int64))
    tiny = FleetSim(plans, topo, activ, WL, COMP, req,
                    np.random.default_rng(5),
                    qcfg=QueueConfig(dt_s=0.02, buffer_s=0.5))
    res = tiny.run()
    assert res.plans[0].drop_rate > 0.0
    roomy = FleetSim(plans, topo, activ, WL, COMP, req,
                     np.random.default_rng(5),
                     qcfg=QueueConfig(dt_s=0.02, buffer_s=1e9))
    assert roomy.run().plans[0].drop_rate == 0.0


def test_kv_admission_cap():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)[:1]
    req = RequestBatch(
        arrival_s=np.arange(20) * 1e-3,       # all in flight at once
        prompt_len=np.full(20, 2), decode_len=np.full(20, 8),
        station=np.zeros(20, dtype=np.int64))
    capped = FleetSim(plans, topo, activ, WL, COMP, req,
                      np.random.default_rng(5),
                      qcfg=QueueConfig(dt_s=0.02, kv_slots=4))
    res = capped.run()
    assert 0.0 < res.plans[0].drop_rate <= 1.0 - 4 / 20 + 1e-9
    uncapped = FleetSim(plans, topo, activ, WL, COMP, req,
                        np.random.default_rng(5),
                        qcfg=QueueConfig(dt_s=0.02, kv_slots=0))
    assert uncapped.run().plans[0].drop_rate == 0.0


# --------------------------------------------------------------------- #
# Arrival processes
# --------------------------------------------------------------------- #


def test_poisson_arrivals_rate_and_order():
    rng = np.random.default_rng(0)
    t = poisson_arrivals(50.0, 100.0, rng)
    assert (np.diff(t) > 0).all() and t[-1] < 100.0
    assert abs(len(t) - 5000) < 5 * np.sqrt(5000)


def test_sample_requests_shapes_and_bounds():
    rng = np.random.default_rng(1)
    req = sample_requests(rng, rate_rps=20.0, horizon_s=50.0, n_stations=4,
                          prompt_max=128, decode_max=64)
    assert req.n_requests > 0
    assert req.prompt_len.max() <= 128 and req.decode_len.max() <= 64
    assert req.station.min() >= 0 and req.station.max() < 4
    sub = req.subset(req.station == 2)
    assert (sub.station == 2).all()
    assert req.request_of_token().shape == (req.total_decode_tokens,)


def test_hotspot_concentrates_on_station():
    rng = np.random.default_rng(2)
    req = sample_requests(rng, rate_rps=40.0, horizon_s=100.0, n_stations=4,
                          arrival="hotspot", hotspot_station=1,
                          hotspot_boost=6.0)
    counts = np.bincount(req.station, minlength=4)
    assert counts[1] > 1.5 * counts[0]


def test_diurnal_modulation_varies_rate():
    rng = np.random.default_rng(3)
    req = sample_requests(rng, rate_rps=40.0, horizon_s=200.0, n_stations=1,
                          arrival="diurnal", diurnal_amplitude=1.0,
                          diurnal_period_s=200.0)
    half = req.arrival_s < 100.0
    # sin > 0 on the first half-period: the busy half must dominate
    assert half.sum() > 1.3 * (~half).sum()


# --------------------------------------------------------------------- #
# Ground segment + ingress offsets
# --------------------------------------------------------------------- #


def test_ground_segment_geometry():
    con, topo, activ = _world()
    g = build_ground_segment(con, LinkConfig(), min_elevation_deg=10.0)
    assert 0.5 < g.coverage() <= 1.0
    seen = g.ingress_sat >= 0
    # visible choices respect the elevation mask
    assert (g.elevation_rad[seen] >= np.deg2rad(10.0) - 1e-9).all()
    # uplink at least the vertical light time to the shell
    min_up = con.cfg.altitude_km * 1e3 / 299_792_458.0
    assert (g.uplink_s[seen] >= min_up).all()
    assert np.isinf(g.uplink_s[~seen]).all()


def test_ingress_offsets_uses_gateway_row():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    batch = PlanBatch.from_plans(plans, topo)
    slots = np.array([0, 1, 2])
    ing = np.array([3, 4, 5])
    off = ingress_offsets(batch, slots, ing)
    assert off.shape == (2, 3)
    for p, plan in enumerate(plans):
        for t in range(3):
            row = batch.g_idx[p, 0]
            assert off[p, t] == batch.dist[slots[t], row, ing[t]]


# --------------------------------------------------------------------- #
# Scenarios, failure storm, saturation
# --------------------------------------------------------------------- #


def test_scenario_registry_names():
    for name in ("smoke", "steady-state", "diurnal-peak",
                 "regional-hotspot", "failure-storm"):
        assert get_scenario(name).name == name
    assert set(SCENARIOS) >= {"smoke", "failure-storm"}
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_run_scenario_smoke_end_to_end():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    sc = dataclasses.replace(get_scenario("smoke"), horizon_s=30.0,
                             tail_s=30.0)
    out = run_scenario(sc, plans, topo, activ, WL, COMP,
                       np.random.default_rng(4), constellation=con)
    rows = out.result.table(sc.slo, scenario="smoke")
    assert {r["plan"] for r in rows} == {"SpaceMoE", "RandIntra-CG"}
    assert all(np.isfinite(r["goodput_tok_s"]) for r in rows)


def test_failure_storm_degrades_and_migrates():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    storm = apply_failure_storm(plans, activ, np.random.default_rng(0),
                                failure_frac=0.5, bytes_per_expert=1e6)
    for old, new in zip(plans, storm.degraded_plans):
        # survivors host multiple experts; all hosts drawn from old hosts
        for layer in range(activ.n_layers):
            hosts = set(new.expert_sats[layer])
            assert hosts <= set(np.asarray(old.expert_sats)[layer])
            assert len(hosts) < activ.n_experts
        assert storm.migration_bytes[new.name] > 0
    sc = dataclasses.replace(get_scenario("failure-storm"), horizon_s=40.0,
                             failure_at_s=20.0, base_rate_rps=0.4,
                             tail_s=30.0, decode_mean=4, decode_max=8,
                             prompt_median=4, prompt_max=16)
    out = run_scenario(sc, plans, topo, activ, WL, COMP,
                       np.random.default_rng(6), constellation=con)
    assert out.post_failure is not None and out.storm is not None
    # degraded fleet: colocation contention can only slow decode down
    pre = out.result.by_name("SpaceMoE").quantile("tpot", 0.5)
    post = out.post_failure.by_name("SpaceMoE+storm").quantile("tpot", 0.5)
    assert post >= pre * 0.95


def test_saturation_sweep_nested_and_positive():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    rng = np.random.default_rng(8)
    req = sample_requests(rng, rate_rps=2.0, horizon_s=40.0, n_stations=1,
                          prompt_median=4, prompt_max=16, decode_mean=4,
                          decode_max=8)
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=30.0))
    slo = get_scenario("smoke").slo
    sat = saturation_sweep(sim, slo, np.random.default_rng(1),
                           fractions=np.array([0.25, 1.0]))
    assert (np.diff(sat.tested_rps) >= 0).all()
    assert sat.sustained_rps["SpaceMoE"] > 0.0
    ratio = sat.capacity_ratio("SpaceMoE", "RandIntra-CG")
    assert ratio > 0.0
