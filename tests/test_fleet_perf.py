"""Fused fleet-simulator guards: compilation stability of run_many (a
whole rate sweep = exactly one trace of the fused kernel), fused<->legacy
parity on smoke and regional-hotspot scenarios (including the AIMD
admission regime), run vs run_many consistency, and Pallas deposit-kernel
parity with the scatter-add reference in interpret mode."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FleetSim, QueueConfig,
                           build_ground_segment, get_scenario,
                           sample_requests)
from repro.traffic import queueing

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    ground = build_ground_segment(con, LinkConfig(), min_elevation_deg=10.0)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, n_layers, n_experts,
                                np.random.default_rng(7))]
    return con, topo, activ, ground, plans


def _assert_parity(res_fused, res_legacy, rtol=1e-5):
    """Identical served/shed/retry sets; latency quantiles to rtol."""
    for pf, pl in zip(res_fused.plans, res_legacy.plans):
        np.testing.assert_array_equal(pf.served, pl.served)
        assert (pf.shed is None) == (pl.shed is None)
        if pf.shed is not None:
            np.testing.assert_array_equal(pf.shed, pl.shed)
            np.testing.assert_array_equal(pf.retries, pl.retries)
        for which in ("ttft", "e2e", "tpot"):
            for q in (0.5, 0.99):
                a, b = pf.quantile(which, q), pl.quantile(which, q)
                assert (np.isnan(a) and np.isnan(b)) \
                    or np.isclose(a, b, rtol=rtol), (which, q, a, b)
        np.testing.assert_allclose(pf.ttft_s, pl.ttft_s, rtol=rtol,
                                   equal_nan=True)
        np.testing.assert_allclose(pf.e2e_s, pl.e2e_s, rtol=rtol,
                                   equal_nan=True)
        assert pf.goodput_tok_s == pl.goodput_tok_s


# --------------------------------------------------------------------- #
# Fused <-> legacy parity
# --------------------------------------------------------------------- #


def test_fused_matches_legacy_smoke_with_kv_cap():
    """Smoke-style trace under the static KV cap: the fused single-launch
    fixed point must reproduce the host loop (served sets identical,
    quantiles within 1e-5)."""
    con, topo, activ, ground, plans = _world()
    req = sample_requests(np.random.default_rng(8), rate_rps=2.0,
                          horizon_s=40.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=30.0, kv_slots=4))
    _assert_parity(sim.run(), sim.run_legacy())


def test_fused_matches_legacy_hotspot_admission():
    """Regional-hotspot overload under the AIMD controller with gateway
    retry: identical shed/retry resolution and latency parity."""
    con, topo, activ, ground, plans = _world()
    sc = dataclasses.replace(get_scenario("regional-hotspot"),
                             horizon_s=40.0)
    req = sc.requests(np.random.default_rng(9), ground.n_stations,
                      rate_scale=5.0)
    qcfg = QueueConfig(dt_s=0.05, tail_s=40.0,
                       admission=AdmissionConfig(ttft_target_s=15.0))
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5), qcfg=qcfg, ground=ground)
    res_f, res_l = sim.run(), sim.run_legacy()
    assert any(p.shed_rate > 0 for p in res_f.plans)   # genuinely shedding
    _assert_parity(res_f, res_l)
    # The backlog observation the replan controller reads survives the
    # fused path's row compaction (expanded back to every satellite).
    assert sim.last_wait.shape == (len(plans), topo.n_sats, sim.n_bins)


def test_fused_matches_legacy_with_schedule_migration():
    """A switching PlanSchedule's migration background load is deposited
    identically by both paths."""
    from repro.core import PlanSchedule
    con, topo, activ, ground, plans = _world()
    sched = PlanSchedule(plans=plans,
                         slot_plan=np.array([0, 1] * 5), name="flip")
    req = sample_requests(np.random.default_rng(3), rate_rps=1.0,
                          horizon_s=60.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0, slot_period_s=20.0,
                       migration_bytes_per_expert=1e6)
    sim = FleetSim([sched], topo, activ, WL, COMP, req,
                   np.random.default_rng(5), qcfg=qcfg)
    assert sim._mig_work.size > 0            # migration load present
    _assert_parity(sim.run(), sim.run_legacy())


# --------------------------------------------------------------------- #
# Compilation stability
# --------------------------------------------------------------------- #


def test_run_many_sweep_is_one_trace_and_matches_run():
    """A 5-point rate sweep through run_many triggers exactly one trace
    of the fused kernel; a same-shape re-run triggers none; every sweep
    entry equals the corresponding single run()."""
    con, topo, activ, ground, plans = _world()
    req = sample_requests(np.random.default_rng(37), rate_rps=1.5,
                          horizon_s=37.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=30.0))
    u = np.random.default_rng(1).random(req.n_requests)
    fractions = np.array([0.2, 0.4, 0.6, 0.8, 1.0])
    masks = u[None, :] < fractions[:, None]

    before = queueing.FUSED_TRACE_COUNT
    many = sim.run_many(masks)
    assert queueing.FUSED_TRACE_COUNT == before + 1
    sim.run_many(masks)                      # same shapes: cache hit
    assert queueing.FUSED_TRACE_COUNT == before + 1

    single = sim.run(active=masks[2])
    for pm, ps in zip(many[2].plans, single.plans):
        np.testing.assert_array_equal(pm.served, ps.served)
        np.testing.assert_allclose(pm.ttft_s, ps.ttft_s, rtol=1e-12,
                                   equal_nan=True)
        np.testing.assert_allclose(pm.e2e_s, ps.e2e_s, rtol=1e-12,
                                   equal_nan=True)


def test_run_many_target_axis_matches_per_target_runs():
    """The admission-frontier batching: run_many over TTFT targets equals
    per-target construction-time configs."""
    con, topo, activ, ground, plans = _world()
    sc = dataclasses.replace(get_scenario("regional-hotspot"),
                             horizon_s=30.0)
    req = sc.requests(np.random.default_rng(4), ground.n_stations,
                      rate_scale=4.0)
    targets = np.array([8.0, 30.0])

    def make(t):
        return FleetSim(plans[:1], topo, activ, WL, COMP, req,
                        np.random.default_rng(5),
                        qcfg=QueueConfig(
                            dt_s=0.05, tail_s=30.0,
                            admission=AdmissionConfig(ttft_target_s=t)),
                        ground=ground)

    batched = make(targets[0]).run_many(
        np.ones((2, req.n_requests), dtype=bool), ttft_targets=targets)
    for t, res in zip(targets, batched):
        _assert_parity(res, make(t).run())


# --------------------------------------------------------------------- #
# Pallas deposit kernel
# --------------------------------------------------------------------- #


def test_deposit_kernel_matches_ref_interpret():
    """Pallas one-hot-matmul deposit == jnp scatter-add oracle across
    paddings and duplicate targets (interpret mode on CPU; tolerance
    covers reduction-order freedom when duplicates collide in f32)."""
    from repro.kernels.ops import deposit
    from repro.kernels.ref import deposit_ref
    rng = np.random.default_rng(0)
    for n_rows, n_cols, n in [(17, 300, 1000), (144, 2568, 4096),
                              (8, 128, 7)]:
        rows = jnp.asarray(rng.integers(0, n_rows, n).astype(np.int32))
        cols = jnp.asarray(rng.integers(0, n_cols, n).astype(np.int32))
        vals = jnp.asarray(rng.random(n).astype(np.float32))
        out = deposit(rows, cols, vals, n_rows, n_cols, block_r=64,
                      block_c=256, block_t=128, interpret=True)
        ref = deposit_ref(rows, cols, vals, n_rows, n_cols)
        assert out.shape == (n_rows, n_cols)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_deposit_kernel_float64_interpret():
    """f64 deposits (the fused path's accumulation dtype) stay exact in
    interpret mode under scoped x64."""
    from repro.kernels.ops import deposit
    from repro.kernels.ref import deposit_ref
    rng = np.random.default_rng(1)
    with queueing._x64():
        rows = jnp.asarray(rng.integers(0, 11, 500).astype(np.int32))
        cols = jnp.asarray(rng.integers(0, 97, 500).astype(np.int32))
        vals = jnp.asarray(rng.random(500))
        out = deposit(rows, cols, vals, 11, 97, interpret=True)
        ref = deposit_ref(rows, cols, vals, 11, 97)
        assert out.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-13, atol=1e-15)


def test_deposit_segments_bitwise_vs_ref():
    """The row-bucketed segment-sum deposit is BITWISE equal to the
    scatter-add oracle: the packed-key sort is stable (chunk index in
    the low bits), so per-(row, bin) f64 additions apply in table order,
    exactly like ``deposit_ref``.  Both the packed fast path and the
    ``bucketed=False`` plain segment_sum are pinned."""
    from repro.kernels.ops import deposit_segments
    from repro.kernels.ref import deposit_ref
    rng = np.random.default_rng(2)
    with queueing._x64():
        for n_rows, n_cols, n in [(17, 300, 1000), (144, 2568, 4096),
                                  (8, 128, 7), (3, 5, 0)]:
            rows = jnp.asarray(rng.integers(0, n_rows, n).astype(np.int32))
            cols = jnp.asarray(rng.integers(0, n_cols, n).astype(np.int32))
            vals = jnp.asarray(rng.standard_normal(n))
            ref = np.asarray(deposit_ref(rows, cols, vals, n_rows, n_cols))
            for bucketed in (True, False):
                out = deposit_segments(rows, cols, vals, n_rows, n_cols,
                                       bucketed=bucketed)
                assert out.dtype == jnp.float64
                np.testing.assert_array_equal(np.asarray(out), ref)
        # Row-grouped duplicates (the fleet chunk-table layout): many
        # chunks collide on one (row, bin) — order-sensitive in f64.
        rows = jnp.asarray(np.repeat(np.arange(7), 400).astype(np.int32))
        cols = jnp.asarray(rng.integers(0, 13, 2800).astype(np.int32))
        vals = jnp.asarray(rng.standard_normal(2800))
        np.testing.assert_array_equal(
            np.asarray(deposit_segments(rows, cols, vals, 7, 13)),
            np.asarray(deposit_ref(rows, cols, vals, 7, 13)))


def test_deposit_impl_segments_sim_bitwise():
    """``deposit_impl="segments"`` leaves the fused fleet results
    bit-identical to the default off-TPU scatter — served sets, TTFT and
    E2E traces all exact, so flipping the implementation never moves a
    trace."""
    con, topo, activ, ground, plans = _world()
    sc = dataclasses.replace(get_scenario("smoke"), horizon_s=30.0)
    req = sc.requests(np.random.default_rng(3), ground.n_stations)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0)

    def run(impl):
        sim = FleetSim(plans, topo, activ, WL, COMP, req,
                       np.random.default_rng(5), qcfg=qcfg, ground=ground)
        sim.deposit_impl = impl
        return sim.run()

    a, b = run("ref"), run("segments")
    for pa, pb in zip(a.plans, b.plans):
        np.testing.assert_array_equal(pa.served, pb.served)
        np.testing.assert_array_equal(pa.ttft_s, pb.ttft_s)
        np.testing.assert_array_equal(pa.e2e_s, pb.e2e_s)
        np.testing.assert_array_equal(pa.token_total_s, pb.token_total_s)
