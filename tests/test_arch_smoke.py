"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED, SHAPES, get_config, list_archs,
                           shape_applies, smoke_config)
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill, random_batch)

ALL_ARCHS = list_archs()


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert len(ALL_ARCHS) == 11          # + the paper's llama-moe-3.5b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_is_published_spec(arch):
    cfg = get_config(arch)
    # divisibility sanity on the published numbers
    assert cfg.n_heads % cfg.n_kv_heads == 0
    assert cfg.n_layers % len(cfg.pattern) == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    counts = cfg.param_counts()
    assert counts["active"] <= counts["total"]


def test_param_counts_match_public_sizes():
    """Total params within tolerance of the published model sizes."""
    expect = {
        "granite-moe-3b-a800m": (3.3e9, 0.25),
        "deepseek-moe-16b": (16.4e9, 0.15),
        "jamba-1.5-large-398b": (398e9, 0.15),
        "llava-next-mistral-7b": (7.2e9, 0.15),
        "qwen2.5-3b": (3.1e9, 0.20),
        "minicpm-2b": (2.7e9, 0.25),
        "smollm-135m": (135e6, 0.20),
        "mistral-large-123b": (123e9, 0.10),
        "xlstm-350m": (350e6, 0.35),
        "llama-moe-3.5b": (6.7e9, 0.15),
    }
    for arch, (target, tol) in expect.items():
        total = get_config(arch).param_counts()["total"]
        assert abs(total - target) / target < tol, (arch, total, target)


def test_active_params():
    # MoE actives: granite ~800M-class, deepseek ~2.8-3B, llama-moe ~3.5B
    assert get_config("granite-moe-3b-a800m").param_counts()["active"] < 1.4e9
    a = get_config("deepseek-moe-16b").param_counts()["active"]
    assert 2.0e9 < a < 4.5e9
    a = get_config("llama-moe-3.5b").param_counts()["active"]
    assert 3.0e9 < a < 4.2e9


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = random_batch(cfg, batch=2, seq_len=32, seed=1)

    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    # one SGD step keeps everything finite
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2, _ = loss_fn(cfg, new_params, batch)
    assert bool(jnp.isfinite(loss2)), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_path(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = random_batch(cfg, batch=b, seq_len=s, seed=2)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = prefill(cfg, params, prompt, max_len=s + 4)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    pos = jnp.full((b,), s, jnp.int32)
    if cfg.frontend == "audio":
        lg, _ = decode_step(cfg, params, cache, None, pos,
                            embeds=jnp.ones((b, 1, cfg.d_model), jnp.float32))
    else:
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        lg, _ = decode_step(cfg, params, cache, tok, pos)
    assert lg.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(lg).all()), arch


def test_shape_matrix_counts():
    """40 assigned cells; long_500k runs only for jamba + xlstm."""
    total, runnable = 0, 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, _ = shape_applies(cfg, shape)
            runnable += ok
    assert total == 40
    assert runnable == 32          # 8 full-attention archs skip long_500k
    for arch in ("jamba-1.5-large-398b", "xlstm-350m"):
        ok, _ = shape_applies(get_config(arch), SHAPES["long_500k"])
        assert ok
