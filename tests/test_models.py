"""Model-zoo correctness: attention oracle, MoE oracle, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (LayerSpec, ModelConfig, apply_placement,
                          decode_step, forward, init_params, loss_fn,
                          prefill, random_batch)
from repro.models.attention import flash_attention
from repro.models.config import ModelConfig as MC
from repro.models.moe import (capacity, dispatch_indices, moe_apply_local,
                              moe_init, route)

F32 = jnp.float32


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=128, attn_q_chunk=8, attn_kv_chunk=8,
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


# --------------------------------------------------------------------- #
# Attention: chunked flash vs naive softmax oracle
# --------------------------------------------------------------------- #


def naive_attention(q, k, v, q_pos, kv_pos, sliding=0):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    sco = jnp.einsum("bqngd,bknd->bnqgk", qg.transpose(0, 1, 2, 3, 4),
                     k) * hd**-0.5
    mask = q_pos[:, None, :, None, None] >= kv_pos[:, None, None, None, :]
    if sliding:
        mask &= (q_pos[:, None, :, None, None]
                 - kv_pos[:, None, None, None, :]) < sliding
    sco = jnp.where(mask, sco, -1e30)
    p = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bnqgk,bknd->bqngd", p, v)
    return out.reshape(b, s, hq, hd)


@pytest.mark.parametrize("s,qc,kc", [(32, 8, 8), (64, 16, 32), (32, 32, 32)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_matches_naive(s, qc, kc, hq, hkv):
    cfg = tiny_cfg(n_heads=hq, n_kv_heads=hkv, attn_q_chunk=qc, attn_kv_chunk=kc)
    key = jax.random.PRNGKey(0)
    b, hd = 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), F32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), F32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), F32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = flash_attention(cfg, q, k, v, pos, pos)
    ref = naive_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_sliding_window():
    cfg = tiny_cfg(sliding_window=8, attn_q_chunk=8, attn_kv_chunk=8)
    b, s, hq, hd = 1, 32, 4, 8
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), F32)
    k = jax.random.normal(ks[1], (b, s, 2, hd), F32)
    v = jax.random.normal(ks[2], (b, s, 2, hd), F32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = flash_attention(cfg, q, k, v, pos, pos)
    ref = naive_attention(q, k, v, pos, pos, sliding=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------- #
# MoE: dispatch plan properties + oracle equivalence
# --------------------------------------------------------------------- #


def test_dispatch_indices_properties():
    rng = np.random.default_rng(0)
    t, k, e, cap = 64, 2, 8, 32
    idx = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    slot_token, slot_valid, copy_slot, copy_kept = dispatch_indices(idx, e, cap)
    assert bool(copy_kept.all())  # cap is generous: nothing dropped
    # every kept copy's slot belongs to its expert
    flat = np.asarray(idx).reshape(-1)
    slots = np.asarray(copy_slot)
    assert (slots // cap == flat).all()
    # slots are unique among kept copies
    assert len(np.unique(slots)) == t * k
    # slot -> token mapping is the inverse
    st, sv = np.asarray(slot_token), np.asarray(slot_valid)
    for copy_i in range(t * k):
        assert st[slots[copy_i]] == copy_i and sv[slots[copy_i]]


def test_dispatch_drops_overflow_deterministically():
    # all tokens pick expert 0 with cap 4 => 4 kept
    idx = jnp.zeros((16, 1), jnp.int32)
    _, slot_valid, _, copy_kept = dispatch_indices(idx, 4, 4)
    assert int(copy_kept.sum()) == 4
    assert int(slot_valid.sum()) == 4


def dense_moe_oracle(cfg, params, x):
    """Compute every expert on every token, combine with top-k weights."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    weights, idx, _ = route(cfg, params["router"], xt)
    outs = []
    for e in range(cfg.n_experts):
        g = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        outs.append(g @ params["w_down"][e])
    all_out = jnp.stack(outs, axis=1)                       # (T, E, d)
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)
    y = jnp.einsum("tkd,tk->td", sel, weights)
    if cfg.n_shared_experts:
        sh = params["shared"]
        y = y + (jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"])) @ sh["w_down"]
    return y.reshape(b, s, d)


@pytest.mark.slow
@pytest.mark.parametrize("e,k,shared", [(8, 2, 0), (16, 4, 0), (8, 2, 2)])
def test_moe_local_matches_dense_oracle(e, k, shared):
    cfg = tiny_cfg(pattern=(LayerSpec("attn", "moe"),), n_experts=e, top_k=k,
                   d_ff_expert=16, n_shared_experts=shared,
                   capacity_factor=8.0)   # generous: dropless
    params = moe_init(jax.random.PRNGKey(0), cfg, F32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), F32)
    y, aux = moe_apply_local(cfg, params, x, F32)
    ref = dense_moe_oracle(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
    assert np.isfinite(float(aux["load_balance_loss"]))


def test_moe_placement_transform_is_equivalent():
    """apply_placement permutes weights+router consistently => same output."""
    cfg = tiny_cfg(pattern=(LayerSpec("attn", "moe"),), n_experts=8, top_k=2,
                   d_ff_expert=16, capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, F32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), F32)
    y0, _ = moe_apply_local(cfg, params, x, F32)
    perm = np.random.default_rng(3).permutation(8)
    y1, _ = moe_apply_local(cfg, apply_placement(params, perm), x, F32)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_capacity_formula():
    cfg = tiny_cfg(pattern=(LayerSpec("attn", "moe"),), n_experts=8, top_k=2,
                   d_ff_expert=16, capacity_factor=1.25)
    assert capacity(cfg, 64, 8) == int(np.ceil(1.25 * 64 * 2 / 8))
    assert capacity(cfg, 1, 8) >= cfg.top_k


# --------------------------------------------------------------------- #
# Decode consistency: prefill + step == full forward
# --------------------------------------------------------------------- #


ARCH_CASES = {
    "dense_gqa": dict(),
    "qkv_bias": dict(qkv_bias=True),
    "moe": dict(pattern=(LayerSpec("attn", "moe"),), n_experts=4, top_k=2,
                d_ff_expert=16, capacity_factor=8.0),
    "mamba": dict(pattern=(LayerSpec("mamba", "dense"),), n_heads=4,
                  n_kv_heads=4),
    "mlstm": dict(pattern=(LayerSpec("mlstm", "none"),), tie_embeddings=True),
    "slstm": dict(pattern=(LayerSpec("slstm", "none"),), tie_embeddings=True),
    "hybrid": dict(pattern=(LayerSpec("attn", "dense"),
                            LayerSpec("mamba", "dense")), n_layers=4),
}


@pytest.mark.slow
@pytest.mark.parametrize("case", list(ARCH_CASES))
def test_decode_matches_forward(case):
    cfg = tiny_cfg(**ARCH_CASES[case])
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                cfg.vocab_size)
    # full forward over s+1 tokens: logits at position s
    logits_full, _ = forward(cfg, params, {"tokens": tokens})
    want = logits_full[:, s, :]
    # prefill s tokens, then decode token s
    _, cache = prefill(cfg, params, {"tokens": tokens[:, :s]}, max_len=s + 4)
    got, _ = decode_step(cfg, params, cache, tokens[:, s:s + 1],
                         jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_training_step_reduces_loss():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = random_batch(cfg, 4, 16, seed=0)

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(
            lambda pp: loss_fn(cfg, pp, batch), has_aux=True
        )(p)
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
        return p, l

    losses = []
    for _ in range(8):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()
