"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import decode_attention, expert_ffn_pallas, gmm
from repro.kernels.ref import decode_attention_ref, gmm_ref


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------- #
# moe_gmm
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,c,k,n",
    [
        (4, 128, 256, 128),      # aligned
        (8, 96, 64, 48),         # needs padding on every axis
        pytest.param(1, 8, 512, 128,       # single expert, tall K
                     marks=pytest.mark.slow),
        pytest.param(16, 256, 128, 384,    # many experts
                     marks=pytest.mark.slow),
        (3, 130, 100, 36),       # awkward primes
    ],
)
def test_gmm_matches_ref(e, c, k, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (e, c, k), dtype)
    w = jax.random.normal(ks[1], (e, k, n), dtype)
    out = gmm(x, w, block_c=64, block_n=128, block_k=64, interpret=True)
    ref = gmm_ref(x, w)
    assert out.shape == (e, c, n) and out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_gmm_block_shape_invariance():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (4, 128, 64), jnp.float32)
    ref = gmm_ref(x, w)
    for bc, bn, bk in [(8, 128, 128), (64, 128, 32), (32, 128, 64)]:
        out = gmm(x, w, block_c=bc, block_n=bn, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_expert_ffn_pallas_matches_moe_layer():
    from repro.models.moe import expert_ffn
    e, c, d, f = 4, 32, 64, 48
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    params = {
        "w_gate": jax.random.normal(ks[0], (e, d, f), jnp.float32) * 0.1,
        "w_up": jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1,
        "w_down": jax.random.normal(ks[2], (e, f, d), jnp.float32) * 0.1,
    }
    xs = jax.random.normal(ks[3], (e, c, d), jnp.float32)
    out = expert_ffn_pallas(params, xs, jnp.float32, interpret=True)
    ref = expert_ffn(params, xs, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------- #
# decode_attn
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hkv,g,s,hd,bs",
    [
        pytest.param(2, 2, 4, 1024, 128, 512,    # aligned
                     marks=pytest.mark.slow),
        (1, 1, 1, 333, 64, 128),      # MQA, ragged S
        pytest.param(4, 8, 12, 256, 128, 256,    # mistral-like grouping
                     marks=pytest.mark.slow),
        (2, 2, 3, 96, 64, 64),        # tiny G (sublane padding)
    ],
)
def test_decode_attn_matches_ref(b, hkv, g, s, hd, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), dtype)
    pos = jax.random.randint(ks[3], (b,), 0, s)
    out = decode_attention(q, k, v, pos, block_s=bs, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    assert out.shape == q.shape and out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_decode_attn_respects_mask_strictly():
    """Garbage beyond pos must not leak into the output."""
    b, hkv, g, s, hd = 1, 1, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), jnp.float32)
    pos = jnp.array([17], jnp.int32)
    out1 = decode_attention(q, k, v, pos, block_s=64, interpret=True)
    # poison everything past pos
    k2 = k.at[:, :, 18:].set(1e9)
    v2 = v.at[:, :, 18:].set(-1e9)
    out2 = decode_attention(q, k2, v2, pos, block_s=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_decode_attn_matches_model_attention():
    """Kernel agrees with the model's jnp decode-attention core."""
    from repro.models.attention import NEG_INF  # noqa: F401  (same mask rule)
    b, hkv, g, s, hd = 2, 4, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), jnp.float32)
    pos = jnp.array([13, 63], jnp.int32)
    out = decode_attention(q, k, v, pos, block_s=32, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
