"""Metrics-surface guards: zero-served and degenerate-span edge cases
of :class:`PlanTraffic` (NaN-safe quantiles, zero goodput, empty
station-util), stable ``row()`` columns with NaN rendering and SLO-miss
marking, and ``format_table`` column/width behavior."""
import math

import numpy as np

from repro.traffic.metrics import SLO, PlanTraffic, format_table


def _plan(n=6, served_mask=None, span_s=10.0, station_util=None,
          shed=None, retries=None):
    """A hand-built PlanTraffic row with controllable degeneracies."""
    served = np.zeros(n, dtype=bool) if served_mask is None \
        else np.asarray(served_mask, dtype=bool)
    lat = np.where(served, 1.0 + np.arange(n, dtype=np.float64), np.nan)
    return PlanTraffic(
        plan_name="toy",
        active=np.ones(n, dtype=bool),
        served=served,
        ttft_s=lat,
        tpot_s=lat / 10.0,
        e2e_s=lat * 2.0,
        decode_len=np.full(n, 5, dtype=np.int64),
        station_util=np.array([0.25, 0.5]) if station_util is None
        else np.asarray(station_util, dtype=np.float64),
        span_s=span_s,
        token_total_s=lat,
        shed=shed,
        retries=retries,
    )


# --------------------------------------------------------------------- #
# Zero-served / degenerate edge cases
# --------------------------------------------------------------------- #


def test_zero_served_is_nan_safe():
    """Nothing served: quantiles are NaN (not a crash), rates are 0,
    the SLO is unmet, and row() still renders every column."""
    p = _plan(served_mask=np.zeros(6, dtype=bool))
    for which in ("ttft", "tpot", "e2e"):
        assert math.isnan(p.quantile(which, 0.99))
    assert p.goodput_tok_s == 0.0
    assert p.drop_rate == 1.0
    assert p.retry_rate == 0.0
    assert not p.meets(SLO())
    row = p.row(SLO())
    assert row["slo_met"] is False
    assert math.isnan(row["ttft_p99_s"])
    assert row["goodput_tok_s"] == 0.0


def test_degenerate_span_yields_zero_rates():
    """span_s <= 0 (single-arrival traces): offered/goodput rates are
    0.0 instead of inf/ZeroDivision."""
    for span in (0.0, -1.0):
        p = _plan(served_mask=np.ones(6, dtype=bool), span_s=span)
        assert p.offered_rps == 0.0
        assert p.goodput_tok_s == 0.0


def test_empty_station_util_and_no_active():
    """Empty station-util arrays and all-inactive traces stay finite."""
    p = _plan(served_mask=np.zeros(6, dtype=bool), station_util=[])
    assert p.row()["max_util"] == 0.0
    p2 = _plan(served_mask=np.zeros(6, dtype=bool))
    p2.active = np.zeros(6, dtype=bool)
    assert p2.n_active == 0
    assert p2.offered_rps == 0.0
    assert p2.drop_rate == 0.0 and p2.shed_rate == 0.0


def test_quantile_filters_nonfinite():
    """Served-but-non-finite latencies (zero-decode TPOT) are excluded;
    an all-non-finite served set returns NaN."""
    p = _plan(n=4, served_mask=np.ones(4, dtype=bool))
    p.tpot_s = np.array([0.1, np.nan, np.inf, 0.3])
    assert p.quantile("tpot", 0.5) == 0.2
    p.tpot_s = np.full(4, np.nan)
    assert math.isnan(p.quantile("tpot", 0.5))


# --------------------------------------------------------------------- #
# row() columns, SLO marking
# --------------------------------------------------------------------- #

EXPECTED_COLS = [
    "plan", "offered_rps", "goodput_tok_s", "drop_rate", "shed_rate",
    "retry_rate", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
    "e2e_p99_s", "max_util", "migration_mb",
]


def test_row_column_stability():
    """row() column names and order are a stable contract (the JSON
    artifacts and bench baselines key on them); slo_met appends last."""
    p = _plan(served_mask=np.ones(6, dtype=bool))
    assert list(p.row().keys()) == EXPECTED_COLS
    assert list(p.row(SLO()).keys()) == EXPECTED_COLS + ["slo_met"]


def test_row_slo_marking():
    """slo_met flips with the objective, not the traffic."""
    p = _plan(served_mask=np.ones(6, dtype=bool))
    assert p.row(SLO(ttft_s=100.0, tpot_s=10.0))["slo_met"] is True
    assert p.row(SLO(ttft_s=0.5))["slo_met"] is False
    # Involuntary drops beyond max_drop break the SLO even when the
    # served latencies are fine.
    half = np.arange(6) < 3
    p2 = _plan(served_mask=half)
    assert p2.row(SLO(ttft_s=100.0, tpot_s=10.0,
                      max_drop=0.01))["slo_met"] is False
    assert p2.row(SLO(ttft_s=100.0, tpot_s=10.0,
                      max_drop=0.6))["slo_met"] is True


def test_shed_excluded_from_drop_rate():
    """Controller sheds are voluntary: they count in shed_rate and are
    subtracted out of drop_rate."""
    served = np.array([True, True, False, False])
    shed = np.array([False, False, True, False])
    p = _plan(n=4, served_mask=served, shed=shed,
              retries=np.array([0, 2, 0, 0]))
    assert p.shed_rate == 0.25
    assert p.drop_rate == 0.25          # only the involuntary failure
    assert p.retry_rate == 0.5          # one of two served retried


# --------------------------------------------------------------------- #
# format_table
# --------------------------------------------------------------------- #


def test_format_table_renders_nan_and_missing():
    """NaN cells render literally, missing keys render empty, and every
    line is padded to the widest cell of its column."""
    rows = [
        {"plan": "a", "ttft_p99_s": float("nan"), "extra": 1},
        {"plan": "longer-name", "ttft_p99_s": 2.5},
    ]
    text = format_table(rows)
    lines = text.splitlines()
    assert len(lines) == 3
    header = lines[0]
    assert header.split() == ["plan", "ttft_p99_s", "extra"]
    assert "nan" in lines[1]
    # Missing 'extra' in row 2 renders as padding, not a crash.
    assert lines[2].startswith("longer-name")
    # Column alignment: the NaN cell starts exactly under its header.
    start = header.index("ttft_p99_s")
    assert lines[1][start:start + 3] == "nan"


def test_format_table_prefix_and_empty():
    assert format_table([]) == "(no rows)"
    assert format_table([], prefix="# ") == "# (no rows)"
    text = format_table([{"a": 1}], prefix="[x] ")
    assert all(ln.startswith("[x] ") for ln in text.splitlines())


def test_format_table_column_order_follows_first_row():
    """Columns come from the first row's insertion order — the renderer
    never sorts or invents columns."""
    rows = [{"b": 1, "a": 2}, {"a": 3, "b": 4, "c": 5}]
    header = format_table(rows).splitlines()[0]
    assert header.split() == ["b", "a"]           # 'c' never appears


# --------------------------------------------------------------------- #
# run_many / run path equality (including degenerate rows)
# --------------------------------------------------------------------- #


def test_run_many_rows_match_per_target_runs():
    """Every sweep row of ``run_many`` — including a degenerate
    all-False mask — reports the same guarded ``offered_rps`` and
    ``goodput_tok_s`` as a standalone ``run`` on that mask, so
    ``saturation_sweep``'s rate axis cannot diverge from per-target
    reruns (both paths read the single guarded property)."""
    from repro.core import (ActivationModel, ComputeConfig, Constellation,
                            ConstellationConfig, LinkConfig, MoEWorkload,
                            rand_intra_cg_plan, sample_topology,
                            spacemoe_plan)
    from repro.traffic import FleetSim, QueueConfig, RequestBatch

    cfg = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
    con = Constellation(cfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, 4, 2, seed=1)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7))]
    n = 40
    req = RequestBatch(
        arrival_s=np.arange(n, dtype=np.float64) * 1.0,
        prompt_len=np.full(n, 2, dtype=np.int64),
        decode_len=np.full(n, 6, dtype=np.int64),
        station=np.zeros(n, dtype=np.int64),
    )
    sim = FleetSim(plans, topo, activ, MoEWorkload.llama_moe_3p5b(),
                   ComputeConfig(), req, np.random.default_rng(0),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=35.0))
    u = np.random.default_rng(3).random(n)
    masks = np.stack([np.zeros(n, dtype=bool),     # degenerate row
                      u < 0.5,
                      np.ones(n, dtype=bool)])
    many = sim.run_many(masks)
    for mask, res in zip(masks, many):
        single = sim.run(mask)
        for pm, ps in zip(res.plans, single.plans):
            assert pm.offered_rps == ps.offered_rps
            assert pm.goodput_tok_s == ps.goodput_tok_s
            np.testing.assert_array_equal(pm.served, ps.served)
    # The degenerate row reads exactly 0.0 on both paths, never a
    # division artifact.
    for p in many[0].plans:
        assert p.offered_rps == 0.0 and p.goodput_tok_s == 0.0
        assert p.n_active == 0
