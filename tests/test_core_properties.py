"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (TorusSpec, activation_probs, esp,
                        expected_dispatch_cost, identity_plan,
                        layer_latency_closed_form, plan_expert_devices,
                        sample_topk, theorem1_assignment)

pos_weights = st.lists(
    st.floats(min_value=0.01, max_value=100.0, allow_nan=False), min_size=3,
    max_size=16,
)


@given(w=pos_weights, data=st.data())
@settings(max_examples=60, deadline=None)
def test_activation_probs_invariants(w, data):
    w = np.asarray(w)
    k = data.draw(st.integers(min_value=1, max_value=len(w)))
    p = activation_probs(w, k)
    assert np.all(p >= -1e-12) and np.all(p <= 1 + 1e-9)
    assert np.isclose(p.sum(), k, rtol=1e-6)
    # monotone: sorting by weight sorts probabilities
    order = np.argsort(w, kind="stable")
    assert np.all(np.diff(p[order]) >= -1e-9)


@given(w=pos_weights, c=st.floats(min_value=0.01, max_value=100.0))
@settings(max_examples=40, deadline=None)
def test_esp_scaling_identity(w, c):
    w = np.asarray(w)
    k = min(3, len(w))
    e1 = esp(w, k)
    e2 = esp(c * w, k)
    for j in range(k + 1):
        assert np.isclose(e2[j], (c**j) * e1[j], rtol=1e-8)


@given(w=pos_weights, data=st.data())
@settings(max_examples=40, deadline=None)
def test_theorem1_beats_random_permutation(w, data):
    """The Theorem-1 placement objective <= any sampled permutation's."""
    w = np.asarray(w)
    n = len(w)
    k = data.draw(st.integers(min_value=1, max_value=n - 1))
    tau = np.sort(
        np.asarray(
            data.draw(
                st.lists(
                    st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
                    min_size=n, max_size=n,
                )
            )
        )
    )
    probs = activation_probs(w, k)
    assign = theorem1_assignment(probs, tau)
    rank_to_expert = np.empty(n, dtype=np.int64)
    rank_to_expert[assign] = np.arange(n)
    opt = layer_latency_closed_form(tau, w, rank_to_expert, k)
    perm = np.asarray(data.draw(st.permutations(range(n))))
    other = layer_latency_closed_form(tau, w, perm, k)
    assert opt <= other + 1e-9


@given(w=pos_weights, data=st.data())
@settings(max_examples=30, deadline=None)
def test_objective_bounds(w, data):
    """tau_K <= tau_c(X) <= tau_I for any placement (slowest-rank support)."""
    w = np.asarray(w)
    n = len(w)
    k = data.draw(st.integers(min_value=1, max_value=n))
    tau = np.sort(np.linspace(0.1, 1.0, n))
    perm = np.asarray(data.draw(st.permutations(range(n))))
    val = layer_latency_closed_form(tau, w, perm, k)
    assert tau[k - 1] - 1e-9 <= val <= tau[-1] + 1e-9


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_sampler_outputs_valid_subsets(data):
    n = data.draw(st.integers(min_value=2, max_value=12))
    k = data.draw(st.integers(min_value=1, max_value=n))
    w = np.asarray(
        data.draw(
            st.lists(
                st.floats(min_value=0.05, max_value=50.0, allow_nan=False),
                min_size=n, max_size=n,
            )
        )
    )
    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    draws = sample_topk(w, k, np.random.default_rng(seed), 8)
    assert draws.shape == (8, k)
    assert draws.min() >= 0 and draws.max() < n
    for row in draws:
        assert len(set(row.tolist())) == k


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_device_placement_never_worse_than_identity(data):
    """TPU transplant: Theorem-1 expert->device permutation cannot increase
    the expected slowest-dispatch cost vs the identity layout."""
    side = data.draw(st.sampled_from([2, 4]))
    epd = data.draw(st.sampled_from([1, 2]))
    torus = TorusSpec(shape=(side, side))
    n_exp = torus.n_devices * epd
    w = np.asarray(
        data.draw(
            st.lists(
                st.floats(min_value=0.05, max_value=20.0, allow_nan=False),
                min_size=n_exp, max_size=n_exp,
            )
        )
    )
    k = data.draw(st.integers(min_value=1, max_value=min(4, n_exp)))
    plan = plan_expert_devices(w, k, torus)
    base = identity_plan(n_exp, torus)
    assert (
        expected_dispatch_cost(plan, w, k)
        <= expected_dispatch_cost(base, w, k) + 1e-12
    )
    # permutation validity
    assert sorted(plan.expert_perm.tolist()) == list(range(n_exp))
