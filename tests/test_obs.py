"""Flight-recorder guards: probes-off bit-parity with the probe-free
kernel (the static-flag invariant), one fused trace per config, probe
ring contents vs the simulator's own backlog trace, AIMD/replan
control-plane events, Chrome-trace export schema validation, and the
Eq. 43 host-side breakdown vs the engine's jitted layer latencies."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        evaluate_schedules, rand_intra_cg_plan,
                        sample_topology, spacemoe_plan)
from repro.core.engine import eq43_layer_terms
from repro.obs import (FlightLog, ProbeConfig, ProbeRecord, build_flight_log,
                       chrome_trace, replan_events, ring_bins,
                       summarize_timeseries, validate_trace)
from repro.traffic import (AdmissionConfig, FleetSim, QueueConfig,
                           ReplanConfig, build_ground_segment,
                           build_replan_schedule, get_scenario,
                           sample_requests)
from repro.traffic import queueing
from repro.traffic.metrics import format_table

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    ground = build_ground_segment(con, LinkConfig(), min_elevation_deg=10.0)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, n_layers, n_experts,
                                np.random.default_rng(7))]
    return con, topo, activ, ground, plans


def _smoke_requests():
    return sample_requests(np.random.default_rng(8), rate_rps=2.0,
                           horizon_s=40.0, n_stations=1, prompt_median=4,
                           prompt_max=16, decode_mean=4, decode_max=8)


# --------------------------------------------------------------------- #
# Pure host-side pieces
# --------------------------------------------------------------------- #


def test_probe_config_resolve():
    """stride=None derives whole-horizon coverage; explicit stride and
    capacity pass through; invalid values raise."""
    assert ProbeConfig(capacity=64).resolve(640) == (64, 10)
    assert ProbeConfig(capacity=64).resolve(641) == (64, 11)
    assert ProbeConfig(capacity=64).resolve(10) == (64, 1)
    assert ProbeConfig(capacity=8, stride=3).resolve(10_000) == (8, 3)
    with pytest.raises(ValueError):
        ProbeConfig(capacity=0)
    with pytest.raises(ValueError):
        ProbeConfig(stride=0)


def test_ring_bins_wrap():
    """The deterministic slot->bin mapping holds with and without ring
    wrap, matching a literal replay of the scan's writes."""
    for n_bins, cap, stride in [(300, 8, 1), (1450, 64, 23), (5, 8, 1),
                                (97, 16, 3), (64, 64, 1), (130, 64, 1)]:
        slots, bins = ring_bins(n_bins, cap, stride)
        # Literal replay: slot (k % cap) holds the last recorded index k.
        ring = {}
        for t in range(0, n_bins, stride):
            ring[(t // stride) % cap] = t
        expect = sorted(ring.items(), key=lambda kv: kv[1])
        assert [s for s, _ in expect] == list(slots), (n_bins, cap, stride)
        assert [b for _, b in expect] == list(bins), (n_bins, cap, stride)
        assert (np.diff(bins) > 0).all()


def test_ring_bins_coverage_is_tail():
    """A wrapped ring keeps exactly the *last* ``capacity`` recorded
    bins — the tail of the horizon, never a stale head."""
    slots, bins = ring_bins(n_bins=300, capacity=8, stride=1)
    assert list(bins) == list(range(292, 300))


# --------------------------------------------------------------------- #
# On-device probes: parity, trace stability, ring contents
# --------------------------------------------------------------------- #


def _build_pair():
    """(probe-free sim, probed sim) on the identical smoke workload."""
    con, topo, activ, ground, plans = _world()
    req = _smoke_requests()
    # tail_s=31 keeps this config's jit-cache entry unique to this module
    # (test_fleet_perf compiles the same world at tail_s=30), so the
    # FUSED_TRACE_COUNT deltas below are deterministic under a full run.
    qcfg = QueueConfig(dt_s=0.05, tail_s=31.0, kv_slots=4)

    def build(probes):
        return FleetSim(plans, topo, activ, WL, COMP, req,
                        np.random.default_rng(5), qcfg=qcfg, probes=probes)

    return build(None), build(ProbeConfig(capacity=64))


def test_probes_off_bit_parity_and_trace_count():
    """probes=None stays bitwise identical to the pre-probe kernel
    across an interleaved probed run, and each config traces the fused
    kernel exactly once (off and probed are separate cache entries)."""
    sim_off, sim_on = _build_pair()
    n0 = queueing.FUSED_TRACE_COUNT
    res_before = sim_off.run()
    n_off = queueing.FUSED_TRACE_COUNT - n0
    assert n_off == 1

    res_on = sim_on.run()
    assert queueing.FUSED_TRACE_COUNT - n0 == 2   # probed kernel: one more

    res_after = sim_off.run()
    assert queueing.FUSED_TRACE_COUNT - n0 == 2   # off kernel: cached
    for pb, pa in zip(res_before.plans, res_after.plans):
        for field in ("ttft_s", "e2e_s", "tpot_s"):
            np.testing.assert_array_equal(getattr(pb, field),
                                          getattr(pa, field))
        np.testing.assert_array_equal(pb.served, pa.served)

    # The probed run reports the same request-level outcome bitwise.
    for pb, po in zip(res_before.plans, res_on.plans):
        np.testing.assert_array_equal(pb.ttft_s, po.ttft_s)
        np.testing.assert_array_equal(pb.served, po.served)

    assert sim_off.last_probes is None
    assert isinstance(sim_on.last_probes, ProbeRecord)


def test_probe_backlog_matches_wait_trace():
    """The ring's backlog channel equals the simulator's full (P, S, T)
    backlog trace at every recorded bin — the probes observe the same
    state the fixed point iterates on."""
    _, sim_on = _build_pair()
    sim_on.run()
    pr = sim_on.last_probes
    assert pr.n_recorded > 0 and not pr.admission_on
    lw = sim_on.last_wait                         # (P, S, T)
    for i, t in enumerate(pr.bins):
        np.testing.assert_array_equal(pr.backlog_s[i, 0], lw[:, :, t])
    # Utilization is per-bin deposited work: bounded by horizon work.
    assert pr.util_s.min() >= 0.0
    assert np.isfinite(pr.util_s).all()


def test_admission_probes_and_aimd_events():
    """Under the AIMD controller the ring records qhat/admit/win, the
    controller actually throttles (admit < 1), and the recorder reads
    >= 1 admit-change event off the ring."""
    con, topo, activ, ground, plans = _world()
    sc = dataclasses.replace(get_scenario("regional-hotspot"),
                             horizon_s=40.0)
    req = sc.requests(np.random.default_rng(9), ground.n_stations,
                      rate_scale=5.0)
    qcfg = QueueConfig(dt_s=0.05, tail_s=40.0,
                       admission=AdmissionConfig(ttft_target_s=15.0))
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(5), qcfg=qcfg, ground=ground,
                   probes=ProbeConfig(capacity=128))
    res = sim.run()
    pr = sim.last_probes
    assert pr.admission_on
    B = pr.n_recorded
    F, P = 1, len(plans)
    assert pr.qhat_s.shape == (B, F, P)
    assert pr.win_s.shape == (B, F, P)
    assert pr.admit.shape[:3] == (B, F, P)
    assert 0.0 < pr.admit.min() < 1.0             # controller engaged
    assert pr.admit.max() <= 1.0

    log = build_flight_log(sim, res, scenario="hotspot")
    aimd = [e for e in log.events if e.kind == "aimd"]
    assert len(aimd) >= 1
    for e in aimd:
        assert 0.0 <= e.args["admit_mean_after"] <= 1.0
        assert e.args["n_gateways_changed"] >= 1


# --------------------------------------------------------------------- #
# Flight log, export, summaries
# --------------------------------------------------------------------- #


def test_flight_log_and_export_schema():
    """A probed smoke run assembles a complete flight log whose Chrome
    trace validates against the schema and contains request spans."""
    _, sim_on = _build_pair()
    res = sim_on.run()
    log = build_flight_log(sim_on, res, scenario="smoke")
    assert isinstance(log, FlightLog)
    assert len(log.requests) == sim_on.requests.n_requests
    assert log.plan == len(res.plans) - 1          # default: last row
    served = log.served()
    assert served and all(r.served for r in served)
    r = served[0]
    assert r.prefill_span[1] == pytest.approx(r.arrival_s + r.ttft_s)
    assert r.layer_zero_s.shape == (4,)            # _world n_layers
    assert r.layer_gw_wait_s is not None
    assert r.queue_wait_s >= 0.0

    trace = chrome_trace(log)
    assert validate_trace(trace) == []
    phs = {e["ph"] for e in trace["traceEvents"]}
    assert {"X", "C", "M"} <= phs
    names = {e["name"] for e in trace["traceEvents"]}
    assert "prefill" in names and "decode" in names


def test_summarize_timeseries_feeds_format_table():
    """The probe ring flattens to windowed rows format_table renders
    with stable columns."""
    _, sim_on = _build_pair()
    sim_on.run()
    rows = summarize_timeseries(sim_on.last_probes, n_windows=6)
    assert 1 <= len(rows) <= 6
    cols = list(rows[0].keys())
    assert cols[:2] == ["t_s", "backlog_max_s"]
    assert all(list(r.keys()) == cols for r in rows)
    assert [r["t_s"] for r in rows] == sorted(r["t_s"] for r in rows)
    text = format_table(rows, prefix="[telemetry] ")
    lines = text.splitlines()
    assert len(lines) == len(rows) + 1
    assert all(ln.startswith("[telemetry] ") for ln in lines)
    assert summarize_timeseries(None) == []


def test_replan_switch_events():
    """A forced-switch replan schedule exports >= 1 'replan switch'
    instant carrying its migration byte flow (and holds export too)."""
    con, topo, activ, ground, plans = _world()
    n_sats = CFG.n_sats

    def drown_incumbent(_k, _t, current):
        b = np.zeros(n_sats)
        cur = plans[max(current, 0)]
        b[np.asarray(cur.gateways)] = 100.0
        b[np.asarray(cur.expert_sats).ravel()] = 100.0
        return b

    report = build_replan_schedule(
        plans, topo, activ, WL, COMP, np.random.default_rng(0),
        ReplanConfig(mode="backlog", migration_weight_s_per_mb=0.0),
        horizon_s=100.0, slot_period_s=30.0, backlog_at=drown_incumbent)
    assert report.n_switches > 0
    events = report.events(slot_period_s=30.0)
    switches = [e for e in events if e.name == "replan switch"]
    assert len(switches) == report.n_switches
    assert all(e.kind == "replan" for e in events)
    assert sum(e.args["migration_bytes"] for e in switches) \
        == pytest.approx(report.total_migration_bytes)
    assert events == replan_events(report, 30.0)
    # Switch instants land at their boundary's wall-clock time.
    for e in switches:
        assert e.t_s == pytest.approx(e.args["boundary"] * 30.0)


def test_replan_scenario_trace_has_aimd_and_switch():
    """End-to-end acceptance: the *-replan scenario under overload
    exports a trace carrying >= 1 AIMD control instant AND >= 1 replan
    switch instant (the control-plane coverage the flight recorder
    exists for), and the trace validates."""
    from repro.obs.schema import count_events
    from repro.traffic import run_scenario

    con, topo, activ, ground, plans = _world()
    base = get_scenario("regional-hotspot-replan")
    sc = dataclasses.replace(
        base, horizon_s=60.0, slot_period_s=20.0,
        admission=AdmissionConfig(ttft_target_s=60.0),
        replan=dataclasses.replace(base.replan, hysteresis=0.0,
                                   migration_weight_s_per_mb=0.0))
    res = run_scenario(sc, plans, topo, activ, WL, COMP,
                       np.random.default_rng(4), ground=ground,
                       constellation=con, rate_scale=12.0,
                       probes=ProbeConfig())
    log = build_flight_log(res.sim, res.result, replan=res.replan,
                           scenario=sc.name)
    trace = chrome_trace(log)
    assert validate_trace(trace) == []
    assert count_events(trace, "aimd", ph="i") >= 1
    assert count_events(trace, "replan switch", ph="i") >= 1
    assert count_events(trace, "prefill", ph="X") >= 1
    # The fleet billed the switches the controller decided.
    assert res.replan.n_switches >= 1
    sw = [e for e in log.events if e.name == "replan switch"]
    assert len(sw) == res.replan.n_switches


# --------------------------------------------------------------------- #
# Eq. 43 breakdown vs the engine
# --------------------------------------------------------------------- #


def test_eq43_layer_terms_matches_engine():
    """The host-side Eq. 43 decomposition reproduces the engine's jitted
    zero-load layer latencies exactly, for every plan row."""
    con, topo, activ, ground, plans = _world()
    sim = FleetSim(plans, topo, activ, WL, COMP, _smoke_requests(),
                   np.random.default_rng(5),
                   qcfg=QueueConfig(dt_s=0.05, tail_s=30.0))
    res = evaluate_schedules(sim.schedules, topo, activ, WL, COMP,
                             np.random.default_rng(0),
                             n_tokens=sim.n_tokens, slots=sim.slots,
                             draws=sim.draws, batch=sim.batch)
    for q, r in enumerate(res):
        lay = np.asarray(r.layer_latency_s)               # (T, L)
        bd = eq43_layer_terms(sim.batch, q, sim.slots,
                              np.asarray(sim.draws),
                              t_gateway=sim.t_gateway,
                              t_expert=sim.t_expert)
        np.testing.assert_allclose(bd["layer_s"], lay, rtol=1e-6,
                                   atol=1e-9, equal_nan=True)
        # The max over branches is what the layer pays; terms stay
        # component-consistent under the decomposition.
        finite = np.isfinite(bd["layer_s"])
        assert finite.any()
        branch = bd["d_out"] + bd["t_exp"] + bd["d_in"]
        np.testing.assert_allclose(
            np.asarray(bd["layer_s"])[finite],
            (sim.t_gateway + np.max(branch, axis=2))[finite], rtol=1e-6)
