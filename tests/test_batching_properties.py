"""Property tests pinning the continuous-batching invariants.

The deposit-time batching law (:mod:`repro.traffic.batching`) was chosen
precisely because its contracts are provable, so this layer pins them:

* **B_max = 1 is bitwise FIFO** — ``s == 1.0`` exactly makes the scaled
  plane an exact multiply-by-zero, at the law level and end-to-end
  through the fused kernel;
* **monotone in B_max** — a larger batch cap never makes any wait, any
  serve decision or the goodput worse (law-level pointwise, end-to-end
  at a congested operating point);
* **work conservation** — batching rescales *service* time, never the
  offered work: the raw offered-work accounting (``station_util``) is
  unchanged;
* **disposition conservation** — under AIMD admission + batching every
  offered request still lands in exactly one of served / shed /
  dropped;
* **static-flag parity** — ``batching=None`` traces the fused kernel
  exactly once and shares the batching-free compile-cache entry.

The law-level contracts run twice: always from a seeded numpy sampler
(tier-1 keeps coverage even without hypothesis installed), and fuzzed
under hypothesis when it is available (heavy example counts ride the
``slow`` nightly tier).  The end-to-end pins run the fast 8x12 world at
fixed seeds.
"""
import numpy as np
import pytest
from jax.experimental import enable_x64

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAS_HYPOTHESIS = False

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, BatchingConfig, FleetSim,
                           QueueConfig, RequestBatch, build_ground_segment,
                           queueing)
from repro.traffic.batching import (batch_speedup_at, batched_effective_work,
                                    effective_work_np, windowed_counts,
                                    windowed_counts_jnp)

# --------------------------------------------------------------------- #
# Law-level contracts (checker functions shared by the seeded sampler
# and the hypothesis wrappers)
# --------------------------------------------------------------------- #


def check_table_contract(sp, b_max, kv):
    cfg = BatchingConfig(b_max=b_max, kv_slots_per_sat=kv,
                         speedup=tuple(sp))
    table = cfg.resolve_table()
    assert table.shape == (cfg.b_cap + 2,)
    assert table[0] == 1.0 and table[1] == 1.0      # s(1) = 1 exactly
    assert np.all(table >= 1.0)
    assert np.all(np.diff(table) >= 0.0)            # clamped monotone
    assert table[-1] == table[-2]                   # flat extension
    assert cfg.b_cap == (min(b_max, kv) if kv > 0 else b_max)


def check_law_contract(sp, b_max, b_hi, window, w, wd, c):
    cfg = BatchingConfig(b_max=b_max, speedup=tuple(sp))
    table = cfg.resolve_table()

    we, beff = effective_work_np(w, wd, c, table, cfg.b_cap, window)
    # Traced form agrees with the host form (window pre-applied); the
    # fused kernel always evaluates these planes under x64.
    with enable_x64():
        we_j, beff_j = batched_effective_work(
            w, wd, np.asarray(windowed_counts_jnp(c, window)), table,
            float(cfg.b_cap))
    np.testing.assert_allclose(np.asarray(we_j), we, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(beff_j), beff, rtol=1e-12)
    # B_eff stays in the admissible band; s >= 1 bounds the plane:
    # batching can only shrink work, and never below the prefill-only
    # residual (work conservation of the non-decode share).
    assert np.all(beff >= 1.0) and np.all(beff <= cfg.b_cap)
    assert np.all(we <= w + 1e-12)
    assert np.all(we >= (w - wd) - 1e-12)
    # Monotone in the cap: a larger B_max never increases any entry.
    t_hi = BatchingConfig(b_max=b_hi, speedup=tuple(sp)).resolve_table()
    we_hi, _ = effective_work_np(w, wd, c, t_hi, b_hi, window)
    assert np.all(we_hi <= we + 1e-12)


def check_bcap1_identity(sp, w, wd, c):
    cfg = BatchingConfig(b_max=1, speedup=tuple(sp))
    table = cfg.resolve_table()
    we, beff = effective_work_np(w, wd, c, table, cfg.b_cap)
    assert np.array_equal(we, w)                     # bitwise
    assert np.all(beff == 1.0)
    with enable_x64():
        we_j, _ = batched_effective_work(w, wd, c, table, 1.0)
    assert np.array_equal(np.asarray(we_j), w)


def check_windowed_counts(cnt, window):
    c = np.asarray(cnt)
    out = windowed_counts(c, window)
    with enable_x64():
        out_j = np.asarray(windowed_counts_jnp(c, window))
    np.testing.assert_allclose(out_j, out, rtol=1e-12)
    assert np.all(out >= c - 1e-12)                  # inclusive of own bin
    assert np.array_equal(windowed_counts(c, 1), c)  # window 1 = identity


def check_speedup_monotone(cnt, sp, b_max):
    c = np.sort(np.asarray(cnt))
    cfg = BatchingConfig(b_max=b_max, speedup=tuple(sp))
    s, beff = batch_speedup_at(c, cfg.resolve_table(), cfg.b_cap)
    assert np.all(np.diff(s) >= -1e-12)
    assert np.all(np.diff(beff) >= -1e-12)


def _sample_planes(rng, n):
    """(work, work_dec, cnt) arrays of length n with work_dec <= work."""
    w = rng.uniform(0.0, 50.0, n)
    wd = w * rng.uniform(0.0, 1.0, n)
    c = rng.uniform(0.0, 40.0, n)
    return w, wd, c


def test_law_contracts_seeded():
    """All law contracts over a seeded numpy sampler — the tier-1 path
    that needs no hypothesis install."""
    rng = np.random.default_rng(2024)
    for _ in range(60):
        n = int(rng.integers(1, 25))
        sp = rng.uniform(0.25, 16.0, int(rng.integers(1, 13)))
        b_max = int(rng.integers(1, 11))
        kv = int(rng.integers(0, 13))
        window = int(rng.integers(1, 5))
        w, wd, c = _sample_planes(rng, n)
        check_table_contract(sp, b_max, kv)
        check_law_contract(sp, b_max, b_max + int(rng.integers(0, 4)),
                           window, w, wd, c)
        check_bcap1_identity(sp, w, wd, c)
        check_windowed_counts(c, window)
        check_speedup_monotone(c, sp, b_max)


if HAS_HYPOTHESIS:
    speedups = st.lists(
        st.floats(min_value=0.25, max_value=16.0, allow_nan=False),
        min_size=1, max_size=12)
    counts = st.lists(
        st.floats(min_value=0.0, max_value=40.0, allow_nan=False),
        min_size=1, max_size=24)

    FAST = dict(max_examples=60, deadline=None)
    HEAVY = dict(max_examples=600, deadline=None)

    def _draw_planes(data, n):
        w = np.asarray(data.draw(st.lists(
            st.floats(min_value=0.0, max_value=50.0, allow_nan=False),
            min_size=n, max_size=n)))
        f = np.asarray(data.draw(st.lists(
            st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
            min_size=n, max_size=n)))
        c = np.asarray(data.draw(st.lists(
            st.floats(min_value=0.0, max_value=40.0, allow_nan=False),
            min_size=n, max_size=n)))
        return w, w * f, c

    def _draw_law_case(data):
        sp = data.draw(speedups)
        b_max = data.draw(st.integers(min_value=1, max_value=10))
        b_hi = data.draw(st.integers(min_value=b_max, max_value=12))
        window = data.draw(st.integers(min_value=1, max_value=4))
        w, wd, c = _draw_planes(data, data.draw(
            st.integers(min_value=1, max_value=24)))
        return sp, b_max, b_hi, window, w, wd, c

    @given(sp=speedups, b_max=st.integers(min_value=1, max_value=12),
           kv=st.integers(min_value=0, max_value=12))
    @settings(**FAST)
    def test_resolve_table_contract(sp, b_max, kv):
        """Speedup tables are padded, clamped monotone, >= 1, s(1)=1."""
        check_table_contract(sp, b_max, kv)

    @given(data=st.data())
    @settings(**FAST)
    def test_batching_law_contract(data):
        """np/jnp agreement, B_eff band, work bounds, cap monotone."""
        check_law_contract(*_draw_law_case(data))

    @given(data=st.data())
    @settings(**FAST)
    def test_bcap1_is_bitwise_identity(data):
        """b_cap = 1 makes the law an exact no-op: work_eff == work
        bit-for-bit, whatever the speedup table said past entry 1."""
        sp = data.draw(speedups)
        w, wd, c = _draw_planes(data, data.draw(
            st.integers(min_value=1, max_value=24)))
        check_bcap1_identity(sp, w, wd, c)

    @given(cnt=counts, window=st.integers(min_value=1, max_value=6))
    @settings(**FAST)
    def test_windowed_counts_np_jnp_agree(cnt, window):
        """Host/traced window sums agree, are causal and inclusive."""
        check_windowed_counts(cnt, window)

    @given(cnt=counts, sp=speedups,
           b_max=st.integers(min_value=1, max_value=10))
    @settings(**FAST)
    def test_speedup_monotone_in_occupancy(cnt, sp, b_max):
        """s(B_eff) is non-decreasing in the occupancy count."""
        check_speedup_monotone(cnt, sp, b_max)

    @pytest.mark.slow
    @given(data=st.data())
    @settings(**HEAVY)
    def test_batching_law_contract_heavy(data):
        """Nightly: the law contract at heavy example counts."""
        check_law_contract(*_draw_law_case(data))

    @pytest.mark.slow
    @given(sp=speedups, b_max=st.integers(min_value=1, max_value=12),
           kv=st.integers(min_value=0, max_value=12))
    @settings(**HEAVY)
    def test_resolve_table_contract_heavy(sp, b_max, kv):
        """Nightly: the table contract at heavy example counts."""
        check_table_contract(sp, b_max, kv)


# --------------------------------------------------------------------- #
# End-to-end pins on the fast world
# --------------------------------------------------------------------- #

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, n_layers, n_experts,
                                np.random.default_rng(7))]
    return con, topo, activ, plans


def _requests(n, gap_s, prompt=4, decode=12):
    return RequestBatch(
        arrival_s=np.arange(n, dtype=np.float64) * gap_s,
        prompt_len=np.full(n, prompt, dtype=np.int64),
        decode_len=np.full(n, decode, dtype=np.int64),
        station=np.zeros(n, dtype=np.int64),
    )


def _sim(topo, activ, plans, req, batching=None, admission=None,
         ground=None, tail_s=33.0):
    # tail_s=33 keeps this module's jit-cache entries distinct from
    # test_obs (31) / test_fleet_perf (30), so the FUSED_TRACE_COUNT
    # deltas below stay deterministic under a full suite run.
    return FleetSim(plans, topo, activ, WL, COMP, req,
                    np.random.default_rng(0),
                    qcfg=QueueConfig(dt_s=0.05, tail_s=tail_s,
                                     admission=admission),
                    ground=ground, batching=batching)


@pytest.fixture(scope="module")
def world():
    return _world()


def _assert_bitwise_equal(res_a, res_b):
    for pa, pb in zip(res_a.plans, res_b.plans):
        np.testing.assert_array_equal(pa.served, pb.served)
        for field in ("ttft_s", "e2e_s", "station_util"):
            np.testing.assert_array_equal(getattr(pa, field),
                                          getattr(pb, field))


def test_bmax1_bitwise_parity_fused(world):
    """B_max = 1 batching is bit-for-bit the FIFO fused kernel."""
    con, topo, activ, plans = world
    req = _requests(120, gap_s=1.0)
    res_fifo = _sim(topo, activ, plans, req).run()
    res_b1 = _sim(topo, activ, plans, req,
                  batching=BatchingConfig(b_max=1)).run()
    _assert_bitwise_equal(res_fifo, res_b1)


def test_kv_slot_bound_pins_fifo(world):
    """One KV slot per satellite caps the batch at 1 regardless of
    B_max: bitwise FIFO again (the occupancy bound, not the b_max pin)."""
    con, topo, activ, plans = world
    req = _requests(120, gap_s=1.0)
    res_fifo = _sim(topo, activ, plans, req).run()
    res_kv = _sim(topo, activ, plans, req,
                  batching=BatchingConfig(b_max=8,
                                          kv_slots_per_sat=1)).run()
    _assert_bitwise_equal(res_fifo, res_kv)


def test_goodput_monotone_in_bmax(world):
    """At a congested operating point, raising B_max never loses serves
    or goodput, and strictly gains somewhere along the sweep."""
    con, topo, activ, plans = world
    req = _requests(120, gap_s=0.6)
    served, goodput = [], []
    for b_max in (1, 2, 4, 8):
        res = _sim(topo, activ, plans, req,
                   batching=BatchingConfig(b_max=b_max)).run()
        served.append(sum(int(p.served.sum()) for p in res.plans))
        goodput.append(sum(p.goodput_tok_s for p in res.plans))
    assert served == sorted(served)
    assert all(b >= a - 1e-9 for a, b in zip(goodput, goodput[1:]))
    assert served[-1] > served[0]        # batching buys real capacity
    assert goodput[-1] > goodput[0]


def test_work_conservation_raw_offered(world):
    """Batching rescales service, never offered work: the raw
    offered-work accounting (station_util) matches FIFO exactly when
    both runs serve everything."""
    con, topo, activ, plans = world
    req = _requests(120, gap_s=1.0)
    res_fifo = _sim(topo, activ, plans, req).run()
    res_b = _sim(topo, activ, plans, req,
                 batching=BatchingConfig(b_max=8)).run()
    for pf, pb in zip(res_fifo.plans, res_b.plans):
        assert pf.served.all() and pb.served.all()
        np.testing.assert_allclose(pb.station_util, pf.station_util,
                                   rtol=1e-12)
        # ... while the experienced latency only improves.
        assert np.nanmean(pb.ttft_s) <= np.nanmean(pf.ttft_s) + 1e-12
        assert np.nanmean(pb.e2e_s) <= np.nanmean(pf.e2e_s) + 1e-12


def test_disposition_conservation_under_admission(world):
    """AIMD admission + batching: every offered request lands in exactly
    one of served / shed / dropped, retries only on served requests."""
    con, topo, activ, plans = world
    ground = build_ground_segment(con, LinkConfig(), min_elevation_deg=10.0)
    req = _requests(120, gap_s=0.6)
    res = _sim(topo, activ, plans, req,
               batching=BatchingConfig(b_max=8),
               admission=AdmissionConfig(ttft_target_s=2.0),
               ground=ground).run()
    for p in res.plans:
        n = p.n_active
        assert n == 120
        served, shed = p.served, p.shed
        assert shed is not None
        assert not np.any(served & shed)             # disjoint
        assert np.all(p.active[served]) and np.all(p.active[shed])
        dropped = p.active & ~served & ~shed
        assert int(served.sum() + shed.sum() + dropped.sum()) == n
        assert abs((1.0 - served.sum() / n) - p.shed_rate
                   - p.drop_rate) < 1e-12
        assert np.all(p.retries[~served] == 0)


def test_batching_off_trace_count_and_cache_share(world):
    """batching=None traces the fused kernel exactly once and shares
    the batching-free cache entry; a batched sim is its own entry."""
    con, topo, activ, plans = world
    req = _requests(60, gap_s=1.0)
    sim_a = _sim(topo, activ, plans, req, tail_s=34.0)
    sim_b = _sim(topo, activ, plans, req, tail_s=34.0)
    n0 = queueing.FUSED_TRACE_COUNT
    sim_a.run()
    assert queueing.FUSED_TRACE_COUNT - n0 == 1
    sim_b.run()                       # identical config: cached
    assert queueing.FUSED_TRACE_COUNT - n0 == 1
    sim_bat = _sim(topo, activ, plans, req, tail_s=34.0,
                   batching=BatchingConfig(b_max=8))
    sim_bat.run()                     # batched kernel: one more entry
    assert queueing.FUSED_TRACE_COUNT - n0 == 2
    sim_a.run()                       # plain kernel still cached
    assert queueing.FUSED_TRACE_COUNT - n0 == 2


@pytest.mark.slow
def test_goodput_monotone_in_bmax_dense(world):
    """Nightly: end-to-end near-monotonicity over a dense B_max grid.

    The law is pointwise monotone at fixed binning; end-to-end the
    fixed-point schedule re-bins deposits between runs, which can
    jitter a marginal request either way — allow that slack while
    pinning the capacity trend.
    """
    con, topo, activ, plans = world
    req = _requests(150, gap_s=0.5)
    served = []
    for b_max in (1, 2, 3, 4, 5, 6, 8, 12):
        res = _sim(topo, activ, plans, req,
                   batching=BatchingConfig(b_max=b_max)).run()
        served.append(sum(int(p.served.sum()) for p in res.plans))
    assert all(b >= a - 2 for a, b in zip(served, served[1:]))
    assert served[-1] > served[0]
