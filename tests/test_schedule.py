"""Time-indexed placement tests: constant-PlanSchedule bit-for-bit parity
with the static engine and fleet paths, the slot -> plan-row gather,
migration-byte parity with distributed.elastic on a hand-checked two-slot
switch, migration background load in the fleet queues, the backlog-driven
re-placement controller (hysteresis + migration gate) and the replan
scenario registry."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, DevicePlacementPlan, LinkConfig,
                        MoEWorkload, PlacementPlan, PlanSchedule,
                        as_schedule, evaluate_plans, evaluate_schedules,
                        migration_between, multi_expert_plan,
                        rand_intra_cg_plan, sample_topology, slot_of_time,
                        spacemoe_plan)
from repro.distributed import migration
from repro.traffic import (SCENARIOS, FleetSim, QueueConfig, ReplanConfig,
                           backlog_penalty_s, build_replan_schedule,
                           get_scenario, replan_traffic, run_scenario,
                           sample_requests)

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    return con, topo, activ


def _plans(con, topo, activ, seed=7):
    return [spacemoe_plan(con, topo, activ),
            rand_intra_cg_plan(con.cfg, activ.n_layers, activ.n_experts,
                               np.random.default_rng(seed))]


# --------------------------------------------------------------------- #
# PlanSchedule basics + the slot -> plan-row gather
# --------------------------------------------------------------------- #


def test_plan_schedule_validation_and_helpers():
    con, topo, activ = _world()
    a, b = _plans(con, topo, activ)
    s = PlanSchedule(plans=[a, b], slot_plan=[0, 0, 1, 1, 0], name="x")
    assert s.n_slots == 5 and s.n_layers == 4 and s.n_experts == 4
    assert not s.is_constant
    np.testing.assert_array_equal(s.switch_slots(), [2, 4])
    assert s.plan_at(2) is b and s.plan_at(4) is a
    assert PlanSchedule.constant(a, 7).is_constant
    assert as_schedule(a, topo.n_slots).n_slots == topo.n_slots
    assert as_schedule(s, 5) is s
    with pytest.raises(ValueError):
        as_schedule(s, 9)                      # wrong slot count
    with pytest.raises(ValueError):
        PlanSchedule(plans=[a], slot_plan=[0, 1])   # index out of range
    with pytest.raises(ValueError):
        PlanSchedule(plans=[], slot_plan=[0])
    np.testing.assert_array_equal(slot_of_time(np.array([0.0, 29.9, 30.0,
                                                         301.0]), 30.0, 10),
                                  [0, 0, 1, 0])


def test_constant_schedule_matches_evaluate_plans_bitwise():
    """The tentpole parity: a constant PlanSchedule through the
    slot -> plan-row gather kernel reproduces the static engine path
    bit-for-bit, for every plan kind and with staleness on."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ) + [multi_expert_plan(con, topo, activ, 2)]
    static = evaluate_plans(plans, topo, activ, WL, COMP,
                            np.random.default_rng(5), n_tokens=300, eta=0.8,
                            route_staleness=2, reroute_penalty_s=0.01)
    sched = evaluate_schedules(plans, topo, activ, WL, COMP,
                               np.random.default_rng(5), n_tokens=300,
                               eta=0.8, route_staleness=2,
                               reroute_penalty_s=0.01)
    for a, b in zip(static, sched):
        np.testing.assert_array_equal(a.token_latency_s, b.token_latency_s)
        np.testing.assert_array_equal(a.layer_latency_s, b.layer_latency_s)


def test_schedule_gather_selects_the_slots_plan():
    """With every token pinned to slot n, a switching schedule must
    equal the static evaluation of exactly plan_at(n) — the gather is
    the plan sequence, not a blend."""
    con, topo, activ = _world()
    a, b = _plans(con, topo, activ)
    sched = PlanSchedule(plans=[a, b],
                         slot_plan=np.arange(topo.n_slots) % 2, name="alt")
    draws = np.stack([activ.sample(layer, np.random.default_rng(3), 64)
                      for layer in range(activ.n_layers)])
    for slot in (0, 1, 5):
        slots = np.full(64, slot, dtype=np.int64)
        got = evaluate_schedules([sched], topo, activ, WL, COMP,
                                 np.random.default_rng(0), n_tokens=64,
                                 slots=slots, draws=draws)[0]
        want = evaluate_plans([sched.plan_at(slot)], topo, activ, WL, COMP,
                              np.random.default_rng(0), n_tokens=64,
                              slots=slots, draws=draws)[0]
        np.testing.assert_array_equal(got.token_latency_s,
                                      want.token_latency_s)


def test_constant_schedule_fleet_parity_bitwise():
    """FleetSim given a plain plan and the same plan wrapped as a
    constant PlanSchedule must agree bit-for-bit, loaded and zero-load."""
    con, topo, activ = _world()
    a, b = _plans(con, topo, activ)
    req = sample_requests(np.random.default_rng(2), rate_rps=2.0,
                          horizon_s=30.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0)
    plain = FleetSim([a, b], topo, activ, WL, COMP, req,
                     np.random.default_rng(5), qcfg=qcfg)
    wrapped = FleetSim([PlanSchedule.constant(a, topo.n_slots),
                        PlanSchedule.constant(b, topo.n_slots)],
                       topo, activ, WL, COMP, req,
                       np.random.default_rng(5), qcfg=qcfg)
    for zero_load in (True, False):
        r0 = plain.run(zero_load=zero_load)
        r1 = wrapped.run(zero_load=zero_load)
        for p0, p1 in zip(r0.plans, r1.plans):
            np.testing.assert_array_equal(p0.served, p1.served)
            np.testing.assert_array_equal(p0.ttft_s, p1.ttft_s)
            np.testing.assert_array_equal(p0.e2e_s, p1.e2e_s)
            np.testing.assert_array_equal(p0.token_total_s, p1.token_total_s)
            assert p1.migration_bytes == 0.0


# --------------------------------------------------------------------- #
# Migration accounting
# --------------------------------------------------------------------- #


def test_migration_bytes_match_distributed_elastic_two_slot_switch():
    """Hand-checked two-slot switch: experts 0 and 1 swap satellites.
    The schedule-level byte accounting must equal distributed.elastic's
    device-ring Migration for the equivalent permutation."""
    bytes_per_expert = 3.5e6
    sats = np.array([10, 20, 30, 40])
    old = PlacementPlan(gateways=np.array([5]),
                        expert_sats=sats[None, :], name="old")
    new = PlacementPlan(gateways=np.array([5]),
                        expert_sats=sats[np.array([1, 0, 2, 3])][None, :],
                        name="new")
    edge = migration_between(old, new, bytes_per_expert)
    assert edge.n_moved == 2
    np.testing.assert_array_equal(edge.experts, [0, 1])
    np.testing.assert_array_equal(edge.old_sats, [10, 20])
    np.testing.assert_array_equal(edge.new_sats, [20, 10])

    # The same switch on the device ring: expert e on device e, then
    # experts 0/1 swap devices.
    identity = DevicePlacementPlan(expert_perm=np.arange(4),
                                   device_cost_s=np.zeros(4),
                                   experts_per_device=1, origin=0)
    swapped = DevicePlacementPlan(expert_perm=np.array([1, 0, 2, 3]),
                                  device_cost_s=np.zeros(4),
                                  experts_per_device=1, origin=0)
    mig = migration(identity, swapped, bytes_per_expert)
    assert set(mig.moved_experts) == set(edge.experts)
    assert mig.bytes_moved == edge.bytes_moved == 2 * bytes_per_expert

    # Wall-clock walk: [old, new, old] over period 10 s crosses two
    # switching boundaries in 25 s (t=10 and t=20).
    sched = PlanSchedule(plans=[old, new], slot_plan=[0, 1, 0], name="s")
    edges = sched.migrations_over(25.0, 10.0, bytes_per_expert)
    assert [t for t, _ in edges] == [10.0, 20.0]
    assert all(e.bytes_moved == 2 * bytes_per_expert for _, e in edges)
    assert sched.total_migration_bytes(bytes_per_expert) \
        == 2 * 2 * bytes_per_expert      # both in-sequence switches


def test_fleet_migration_background_load_occupies_destination_queues():
    """A switching schedule's migration bytes must show up as reported
    migration_bytes and as extra work on the destination satellites
    (inflating waits relative to the migration-free run)."""
    con, topo, activ = _world()
    a, b = _plans(con, topo, activ)
    sched = PlanSchedule(plans=[a, b],
                         slot_plan=(np.arange(topo.n_slots) // 1) % 2,
                         name="alt")
    req = sample_requests(np.random.default_rng(2), rate_rps=2.0,
                          horizon_s=60.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    moved = migration_between(a, b, 1.0).n_moved
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0, slot_period_s=20.0,
                       migration_bytes_per_expert=1e6,
                       migration_rate_gbps=10.0)
    sim = FleetSim([sched], topo, activ, WL, COMP, req,
                   np.random.default_rng(5), qcfg=qcfg)
    res = sim.run()
    n_bounds = len(sched.migrations_over(sim.n_bins * qcfg.dt_s, 20.0, 1e6))
    assert n_bounds > 0
    assert res.plans[0].migration_bytes == n_bounds * moved * 1e6
    # A slower migration link deposits more seconds of background work.
    slow = FleetSim([sched], topo, activ, WL, COMP, req,
                    np.random.default_rng(5),
                    qcfg=dataclasses.replace(qcfg, migration_rate_gbps=1e-3))
    assert slow._mig_work.sum() > sim._mig_work.sum()


# --------------------------------------------------------------------- #
# Re-placement controller
# --------------------------------------------------------------------- #


def test_replan_config_validation():
    with pytest.raises(ValueError):
        ReplanConfig(mode="nope")
    with pytest.raises(ValueError):
        ReplanConfig(period_slots=0)
    with pytest.raises(ValueError):
        ReplanConfig(hysteresis=-0.1)
    with pytest.raises(ValueError):
        ReplanConfig(n_tokens=0)
    with pytest.raises(ValueError):
        ReplanConfig(controller_iterations=0)


def test_backlog_penalty_is_the_critical_path():
    plan = PlacementPlan(gateways=np.array([0, 3]),
                         expert_sats=np.array([[1, 2], [4, 5]]))
    b = np.array([1.0, 0.5, 2.0, 0.25, 0.0, 4.0])
    # gateways 0 + 3, plus per-layer worst expert (2.0 and 4.0)
    assert backlog_penalty_s(plan, b) == pytest.approx(1.0 + 0.25 + 2.0 + 4.0)


def test_replan_off_holds_the_t0_best_plan():
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    rep = build_replan_schedule(
        plans, topo, activ, WL, COMP, np.random.default_rng(0),
        ReplanConfig(mode="off"), horizon_s=200.0, slot_period_s=30.0)
    assert rep.schedule.is_constant
    assert rep.n_switches == 0 and rep.total_migration_bytes == 0.0


def test_backlog_drives_switch_and_migration_gate_blocks_it():
    """Drowning the incumbent's satellites in synthetic backlog must
    force a switch; pricing migration prohibitively must block the same
    switch (the gate)."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    n_sats = CFG.n_sats

    def drown_incumbent(_k, _t, current):
        b = np.zeros(n_sats)
        cur = plans[max(current, 0)]
        b[np.asarray(cur.gateways)] = 100.0
        b[np.asarray(cur.expert_sats).ravel()] = 100.0
        return b

    kw = dict(horizon_s=100.0, slot_period_s=30.0, backlog_at=drown_incumbent)
    free = build_replan_schedule(
        plans, topo, activ, WL, COMP, np.random.default_rng(0),
        ReplanConfig(mode="backlog", migration_weight_s_per_mb=0.0), **kw)
    assert free.n_switches > 0
    gated = build_replan_schedule(
        plans, topo, activ, WL, COMP, np.random.default_rng(0),
        ReplanConfig(mode="backlog", migration_weight_s_per_mb=1e9), **kw)
    assert gated.n_switches == 0


def test_replan_traffic_rows_and_report():
    """The closed loop returns statics + the schedule row, with the
    report's migration bytes consistent with the fleet's accounting."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    req = sample_requests(np.random.default_rng(2), rate_rps=3.0,
                          horizon_s=60.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    out = replan_traffic(plans, topo, activ, WL, COMP, req,
                         np.random.default_rng(4),
                         ReplanConfig(mode="backlog"),
                         QueueConfig(dt_s=0.05, tail_s=30.0,
                                     slot_period_s=20.0, buffer_s=3.0))
    names = [p.plan_name for p in out.result.plans]
    assert names[:2] == [p.name for p in plans]
    assert names[-1] == "replan/backlog"
    assert out.replanned.plan_name == "replan/backlog"
    assert out.best_static().plan_name in names[:2]
    # Switches the horizon crosses are what the fleet bills for.
    crossed = out.report.schedule.migrations_over(
        out.sim.n_bins * 0.05, 20.0, 1e6)
    assert out.replanned.migration_bytes \
        == pytest.approx(sum(e.bytes_moved for _, e in crossed))


# --------------------------------------------------------------------- #
# Scenario registry plumbing
# --------------------------------------------------------------------- #


def test_replan_scenarios_registered():
    for name in ("regional-hotspot-replan", "failure-storm-replan"):
        sc = get_scenario(name)
        assert sc.replan is not None and sc.replan.mode == "backlog"
        assert sc.slot_period_s is not None \
            and sc.slot_period_s < sc.horizon_s       # boundaries inside
    assert set(SCENARIOS) >= {"regional-hotspot-replan",
                              "failure-storm-replan"}


@pytest.mark.slow
def test_replan_scenario_end_to_end_storm():
    """failure-storm-replan: both phases produce a replan row; the post
    phase re-places among the degraded plans."""
    con, topo, activ = _world()
    plans = _plans(con, topo, activ)
    sc = dataclasses.replace(
        get_scenario("failure-storm-replan"), horizon_s=60.0, tail_s=30.0,
        failure_at_s=30.0, slot_period_s=15.0, decode_mean=4, decode_max=8,
        prompt_median=4, prompt_max=16)
    out = run_scenario(sc, plans, topo, activ, WL, COMP,
                       np.random.default_rng(4), constellation=con,
                       rate_scale=3.0)
    assert out.replan is not None and out.post_replan is not None
    assert out.result.by_name("replan/backlog") is not None
    assert out.post_failure.by_name("replan/backlog") is not None
    post_names = {p.plan_name for p in out.post_failure.plans}
    assert any(n.endswith("+storm") for n in post_names)
