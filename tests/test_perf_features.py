"""Perf-feature correctness: EP slotting and custom-VJP flash attention
must be bit-compatible (within fp tolerance) with the baseline math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention
from repro.models.config import LayerSpec, ModelConfig
from repro.models.moe import (make_slotting, moe_apply_local, moe_init,
                              slotted_weights, slotting_for)

F32 = jnp.float32


# --------------------------------------------------------------------- #
# EP slotting
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("e,s,frag,e_pad", [
    (8, 16, 2, 8),        # llama-moe: fragment
    (40, 16, 1, 48),      # granite: pad with dummies
    (6, 16, 2, 8),        # pad then fragment
    (64, 16, 1, 64),      # deepseek: already divisible
    (16, 16, 1, 16),      # jamba: exact
])
def test_make_slotting(e, s, frag, e_pad):
    sl = make_slotting(e, s)
    assert (sl.frag, sl.e_pad) == (frag, e_pad)
    assert sl.n_virtual % s == 0


def _moe_cfg(e, k, slotting, dff=32):
    return ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=128, pattern=(LayerSpec("attn", "moe"),), n_experts=e,
        top_k=k, d_ff_expert=dff, capacity_factor=8.0,
        compute_dtype="float32", moe_slotting=slotting, moe_ep_slots=16,
    )


@pytest.mark.slow
@pytest.mark.parametrize("e,k", [(8, 2), (40, 8), (6, 2), (64, 6)])
def test_slotted_moe_matches_canonical(e, k):
    cfg0, cfg1 = _moe_cfg(e, k, False), _moe_cfg(e, k, True)
    p0 = moe_init(jax.random.PRNGKey(0), cfg0, F32)
    sl = slotting_for(cfg1)
    wg, wu, wd = slotted_weights(p0["w_gate"], p0["w_up"], p0["w_down"], sl)
    p1 = dict(p0, w_gate=wg, w_up=wu, w_down=wd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), F32)
    y0, _ = moe_apply_local(cfg0, p0, x, F32)
    y1, _ = moe_apply_local(cfg1, p1, x, F32)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_slotted_init_shapes():
    cfg = _moe_cfg(8, 2, True)
    p = moe_init(jax.random.PRNGKey(0), cfg, F32)
    assert p["w_gate"].shape == (16, 32, 16)      # 8 experts x 2 half-slots
    assert p["w_down"].shape == (16, 16, 32)
    assert p["router"].shape == (32, 8)           # router stays expert-level


# --------------------------------------------------------------------- #
# custom-VJP flash attention
# --------------------------------------------------------------------- #


def _naive(q, k, v, pos, sliding=0):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    sco = jnp.einsum("bqngd,bknd->bnqgk", qg, k) * hd**-0.5
    mask = pos[:, None, :, None, None] >= pos[:, None, None, None, :]
    if sliding:
        mask &= (pos[:, None, :, None, None]
                 - pos[:, None, None, None, :]) < sliding
    sco = jnp.where(mask, sco, -1e30)
    p = jax.nn.softmax(sco, -1)
    return jnp.einsum("bnqgk,bknd->bqngd", p, v).reshape(b, s, hq, hd)


@pytest.mark.slow
@pytest.mark.parametrize("sw", [0, 8])
@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (8, 1)])
def test_flash_vjp_grads_match_naive(sw, hq, hkv):
    cfg = ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=hq, n_kv_heads=hkv,
        d_ff=64, vocab_size=128, attn_q_chunk=8, attn_kv_chunk=16,
        compute_dtype="float32", flash_vjp=True, sliding_window=sw,
    )
    b, s, hd = 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    tgt = jax.random.normal(ks[3], (b, s, hq, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    g1 = jax.grad(
        lambda *a: jnp.sum((flash_attention(cfg, *a, pos, pos) - tgt) ** 2),
        (0, 1, 2),
    )(q, k, v)
    g2 = jax.grad(
        lambda *a: jnp.sum((_naive(*a, pos, sliding=sw) - tgt) ** 2), (0, 1, 2)
    )(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


@pytest.mark.slow
def test_flash_vjp_whole_model_grads():
    """End-to-end: training grads with flash_vjp == grads without."""
    from repro.models import init_params, loss_fn, random_batch
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=128, attn_q_chunk=8, attn_kv_chunk=8,
                compute_dtype="float32")
    cfg0 = ModelConfig(**base)
    cfg1 = ModelConfig(**base, flash_vjp=True)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = random_batch(cfg0, 2, 16, seed=1)
    g0 = jax.grad(lambda p: loss_fn(cfg0, p, batch)[0])(params)
    g1 = jax.grad(lambda p: loss_fn(cfg1, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


# --------------------------------------------------------------------- #
# Pallas decode kernel wired into the model decode path
# --------------------------------------------------------------------- #


def test_pallas_decode_path_matches_jnp():
    from repro.models import decode_step, init_params, prefill, random_batch
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=128, attn_q_chunk=8, attn_kv_chunk=8,
                compute_dtype="float32")
    cfg0 = ModelConfig(**base)
    cfg1 = ModelConfig(**base, use_pallas_decode=True)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = random_batch(cfg0, b, s, seed=1)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    _, cache0 = prefill(cfg0, params, prompt, max_len=s + 4)
    _, cache1 = prefill(cfg1, params, prompt, max_len=s + 4)
    tok = jnp.full((b, 1), 3, jnp.int32)
    pos = jnp.full((b,), s, jnp.int32)
    l0, _ = decode_step(cfg0, params, cache0, tok, pos)
    l1, _ = decode_step(cfg1, params, cache1, tok, pos)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               atol=2e-4, rtol=2e-4)
