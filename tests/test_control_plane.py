"""Joint control plane: the fused replan+admission decide loop.

``FleetSim.run_replan_grid`` folds probe, the pinned re-placement
decide law and the decided schedule's evaluation into ONE device launch
(``queueing._ctrl_core``); ``replan_traffic`` stays the host-walk
anchor.  These tests pin, on CPU:

* bitwise decision parity (switch boundaries, incumbent sequence,
  scores, migration bytes) and result parity (served/shed sets, TTFT /
  E2E / per-token traces) across modes, a switch-heavy world, the
  hysteresis + migration gates, and the admission-coupled regimes
  (AIMD and PID share the qhat signal with the replan score);
* scenario-level parity: ``run_scenario(..., ctrl="fused")`` reproduces
  the host controller on the registered replan scenarios;
* ``replan=None`` launches stay bit-identical to the legacy host path
  (the control plane rides the same kernel without moving its trace);
* one controller grid (cadence x migration-budget x admission-target)
  costs exactly one trace — the ``FUSED_TRACE_COUNT`` acceptance pin;
* the on-device decision-event channel (``DecisionTrace`` /
  ``joint_decision_events``) mirrors the decisions list.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.obs import DecisionTrace, joint_decision_events
from repro.traffic import (AdmissionConfig, FleetSim, QueueConfig,
                           ReplanConfig, get_scenario, replan_traffic,
                           replan_traffic_fused, run_scenario,
                           sample_requests)
from repro.traffic import queueing

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _quiet_world():
    """Low-rate two-plan world: decisions mostly hold the incumbent."""
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, 4, 2, seed=1)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7))]
    req = sample_requests(np.random.default_rng(2), rate_rps=3.0,
                          horizon_s=60.0, n_stations=1, prompt_median=4,
                          prompt_max=16, decode_mean=4, decode_max=8)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0, slot_period_s=20.0,
                       buffer_s=3.0)
    return topo, activ, plans, req, qcfg


def _switch_world(admission: AdmissionConfig | None = None):
    """Congested three-plan world that forces real plan switches."""
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, 4, 2, seed=1)
    plans = [rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7)),
             spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(11))]
    req = sample_requests(np.random.default_rng(2), rate_rps=40.0,
                          horizon_s=60.0, n_stations=2, prompt_median=8,
                          prompt_max=32, decode_mean=8, decode_max=16)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0, slot_period_s=10.0,
                       buffer_s=6.0 if admission is not None else 3.0,
                       admission=admission)
    return topo, activ, plans, req, qcfg


def _assert_same_report(host, fused):
    """Identical decision trajectory: boundaries, incumbents, scores."""
    assert np.array_equal(host.schedule.slot_plan,
                          fused.schedule.slot_plan)
    assert len(host.decisions) == len(fused.decisions)
    for dh, df in zip(host.decisions, fused.decisions):
        assert (dh.boundary, dh.slot, dh.chosen, dh.switched) \
            == (df.boundary, df.slot, df.chosen, df.switched), (dh, df)
        np.testing.assert_array_equal(dh.scores, df.scores,
                                      err_msg=str(dh))
        assert dh.migration_bytes == df.migration_bytes


def _assert_same_decisions(host, fused):
    _assert_same_report(host.report, fused.report)


def _assert_same_result(host, fused):
    """Bitwise result parity: served/shed sets and latency traces."""
    assert [p.plan_name for p in host.plans] \
        == [p.plan_name for p in fused.plans]
    for ph, pf in zip(host.plans, fused.plans):
        np.testing.assert_array_equal(ph.served, pf.served,
                                      err_msg=ph.plan_name)
        if ph.shed is not None or pf.shed is not None:
            np.testing.assert_array_equal(ph.shed, pf.shed,
                                          err_msg=ph.plan_name)
        np.testing.assert_array_equal(ph.ttft_s, pf.ttft_s,
                                      err_msg=ph.plan_name)
        np.testing.assert_array_equal(ph.e2e_s, pf.e2e_s,
                                      err_msg=ph.plan_name)
        np.testing.assert_array_equal(ph.token_total_s, pf.token_total_s,
                                      err_msg=ph.plan_name)
        assert ph.migration_bytes == pf.migration_bytes


def _run_both(topo, activ, plans, req, qcfg, rcfg, seed=4):
    host = replan_traffic(plans, topo, activ, WL, COMP, req,
                          np.random.default_rng(seed), rcfg, qcfg)
    fused = replan_traffic_fused(plans, topo, activ, WL, COMP, req,
                                 np.random.default_rng(seed), rcfg, qcfg)
    return host, fused


# --------------------------------------------------------------------- #
# Decision + result parity: fused controller vs the host walk
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("mode", ["backlog", "periodic", "off"])
def test_fused_matches_host_all_modes(mode):
    """Every controller mode reproduces the host walk bit for bit on a
    quiet world (decisions mostly hold; scores must still agree)."""
    topo, activ, plans, req, qcfg = _quiet_world()
    host, fused = _run_both(topo, activ, plans, req, qcfg,
                            ReplanConfig(mode=mode))
    _assert_same_decisions(host, fused)
    _assert_same_result(host.result, fused.result)
    if mode == "backlog":
        assert fused.probe is not None
        _assert_same_result(host.probe, fused.probe)


def test_fused_matches_host_switching_world():
    """With the gates zeroed the congested world forces real switches,
    and the fused controller lands every one of them on the host's
    boundaries with the host's incumbent sequence."""
    topo, activ, plans, req, qcfg = _switch_world()
    host, fused = _run_both(
        topo, activ, plans, req, qcfg,
        ReplanConfig(mode="backlog", hysteresis=0.0,
                     migration_weight_s_per_mb=0.0))
    assert host.report.n_switches >= 3      # the world must actually switch
    _assert_same_decisions(host, fused)
    _assert_same_result(host.result, fused.result)


def test_fused_matches_host_gated():
    """Hysteresis and the migration-cost gate (the pinned decide law's
    two dampers) produce identical switch suppression on device."""
    topo, activ, plans, req, qcfg = _switch_world()
    free = ReplanConfig(mode="backlog", hysteresis=0.0,
                        migration_weight_s_per_mb=0.0)
    gated = ReplanConfig(mode="backlog", hysteresis=0.02,
                         migration_weight_s_per_mb=0.001)
    host, fused = _run_both(topo, activ, plans, req, qcfg, gated)
    _assert_same_decisions(host, fused)
    _assert_same_result(host.result, fused.result)
    # The gates must bite somewhere, or this test pins nothing.
    host_free, _ = _run_both(topo, activ, plans, req, qcfg, free)
    assert host.report.n_switches <= host_free.report.n_switches


@pytest.mark.parametrize("policy", ["aimd", "pid"])
def test_fused_matches_host_with_admission(policy):
    """Joint controller: admission (AIMD / PID) and the replan score
    read the same qhat signal inside one launch, and still reproduce
    the host loop's decisions and served/shed sets exactly."""
    topo, activ, plans, req, qcfg = _switch_world(
        AdmissionConfig(policy=policy, ttft_target_s=60.0))
    host, fused = _run_both(
        topo, activ, plans, req, qcfg,
        ReplanConfig(mode="backlog", hysteresis=0.0,
                     migration_weight_s_per_mb=0.0))
    assert host.report.n_switches >= 1
    _assert_same_decisions(host, fused)
    _assert_same_result(host.result, fused.result)


# --------------------------------------------------------------------- #
# Scenario-level parity (the registered replan scenarios)
# --------------------------------------------------------------------- #


def _scenario_world():
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, 4, 2, seed=1)
    plans = [spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7))]
    return con, topo, activ, plans


def test_scenario_parity_regional_hotspot():
    """run_scenario(ctrl="fused") == ctrl="host" on the hotspot replan
    scenario: same schedule, decisions and per-plan traces."""
    con, topo, activ, plans = _scenario_world()
    sc = dataclasses.replace(
        get_scenario("regional-hotspot-replan"), horizon_s=60.0,
        tail_s=30.0, slot_period_s=15.0, decode_mean=4, decode_max=8,
        prompt_median=4, prompt_max=16)
    host = run_scenario(sc, plans, topo, activ, WL, COMP,
                        np.random.default_rng(4), constellation=con,
                        rate_scale=2.0, ctrl="host")
    fused = run_scenario(sc, plans, topo, activ, WL, COMP,
                         np.random.default_rng(4), constellation=con,
                         rate_scale=2.0, ctrl="fused")
    _assert_same_report(host.replan, fused.replan)
    _assert_same_result(host.result, fused.result)
    assert host.replan.trace is None          # host walk: no device telem
    assert isinstance(fused.replan.trace, DecisionTrace)


@pytest.mark.slow
def test_scenario_parity_failure_storm():
    """Both phases of the storm scenario re-place identically under the
    fused controller (the post phase re-decides among degraded plans)."""
    con, topo, activ, plans = _scenario_world()
    sc = dataclasses.replace(
        get_scenario("failure-storm-replan"), horizon_s=60.0, tail_s=30.0,
        failure_at_s=30.0, slot_period_s=15.0, decode_mean=4, decode_max=8,
        prompt_median=4, prompt_max=16)
    host = run_scenario(sc, plans, topo, activ, WL, COMP,
                        np.random.default_rng(4), constellation=con,
                        rate_scale=3.0, ctrl="host")
    fused = run_scenario(sc, plans, topo, activ, WL, COMP,
                         np.random.default_rng(4), constellation=con,
                         rate_scale=3.0, ctrl="fused")
    for rh, rf in ((host.replan, fused.replan),
                   (host.post_replan, fused.post_replan)):
        assert rh is not None and rf is not None
        assert np.array_equal(rh.schedule.slot_plan, rf.schedule.slot_plan)
        for dh, df in zip(rh.decisions, rf.decisions):
            assert (dh.boundary, dh.chosen, dh.switched) \
                == (df.boundary, df.chosen, df.switched)
    _assert_same_result(host.result, fused.result)
    _assert_same_result(host.post_failure, fused.post_failure)


# --------------------------------------------------------------------- #
# replan=None launches stay on the unmodified kernel
# --------------------------------------------------------------------- #


def test_replan_none_bit_identical():
    """``replan=None`` launches ride the unmodified fused trace: a
    controller launch in between must not perturb a plain run bitwise,
    and the plain run keeps the fleet bench's fused/legacy contract
    (identical served sets, latencies to float32 round-off)."""
    topo, activ, plans, req, qcfg = _quiet_world()
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(4), qcfg)
    base = sim.run()
    sim.run(replan=ReplanConfig(mode="backlog"),
            replan_rng=np.random.default_rng(5))
    again = sim.run()
    for pa, pb in zip(base.plans, again.plans):
        np.testing.assert_array_equal(pa.served, pb.served)
        np.testing.assert_array_equal(pa.ttft_s, pb.ttft_s)
        np.testing.assert_array_equal(pa.e2e_s, pb.e2e_s)
        np.testing.assert_array_equal(pa.token_total_s, pb.token_total_s)
    legacy = sim.run_legacy()
    for pf, pl_ in zip(base.plans, legacy.plans):
        np.testing.assert_array_equal(pf.served, pl_.served)
        np.testing.assert_allclose(pf.ttft_s, pl_.ttft_s, rtol=1e-5)
        np.testing.assert_allclose(pf.e2e_s, pl_.e2e_s, rtol=1e-5)


# --------------------------------------------------------------------- #
# One launch per controller grid (the FUSED_TRACE_COUNT pin)
# --------------------------------------------------------------------- #


def test_controller_grid_single_trace():
    """A full 3x3x3 cadence x migration-budget x admission-target grid
    batches the leading axis of ONE device program: exactly one trace,
    27 outcomes, per-cell cadences visible in the decision counts."""
    topo, activ, plans, req, qcfg = _quiet_world()
    qcfg = dataclasses.replace(
        qcfg, buffer_s=6.0,
        admission=AdmissionConfig(policy="aimd", ttft_target_s=60.0))
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(4), qcfg)
    rcfg = ReplanConfig(mode="backlog", hysteresis=0.0,
                        migration_weight_s_per_mb=0.0)
    cadences = [1, 2, 3]
    mig_weights = [0.0, 0.01, 0.1]
    ttft_targets = [30.0, 60.0, 90.0]

    before = queueing.FUSED_TRACE_COUNT
    outcomes = sim.run_many(replan=rcfg, cadences=cadences,
                            mig_weights=mig_weights,
                            ttft_targets=ttft_targets)
    assert queueing.FUSED_TRACE_COUNT - before == 1, \
        "the controller grid must compile as a single device program"
    assert len(outcomes) == 27

    # Cadence-major cell order: decision counts follow the decide mask.
    n_bounds = len(outcomes[0].report.decisions) - 1 \
        if cadences[0] == 1 else None
    for f, out in enumerate(outcomes):
        cad = cadences[f // 9]
        ks = [d.boundary for d in out.report.decisions]
        assert ks[0] == 0
        assert all(k % cad == 0 for k in ks[1:])
        assert isinstance(out.report.trace, DecisionTrace)
    if n_bounds:
        # Coarser cadences decide at strictly fewer boundaries.
        assert len(outcomes[9].report.decisions) \
            < len(outcomes[0].report.decisions)

    # Relaunching the identical grid reuses the compile cache.
    before = queueing.FUSED_TRACE_COUNT
    sim.run_many(replan=rcfg, cadences=cadences, mig_weights=mig_weights,
                 ttft_targets=ttft_targets)
    assert queueing.FUSED_TRACE_COUNT == before


def test_controller_grid_rejects_host_only_paths():
    """Paths where the host controller stays authoritative raise
    instead of silently diverging."""
    from repro.traffic.batching import BatchingConfig
    topo, activ, plans, req, qcfg = _quiet_world()
    rcfg = ReplanConfig(mode="backlog")

    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(4), qcfg,
                   batching=BatchingConfig())
    with pytest.raises(NotImplementedError, match="batching"):
        sim.run(replan=rcfg)

    qcfg_g = dataclasses.replace(
        qcfg, admission=AdmissionConfig(policy="pid",
                                        gain_scale=(1.0, 2.0)))
    sim = FleetSim(plans, topo, activ, WL, COMP, req,
                   np.random.default_rng(4), qcfg_g)
    with pytest.raises(NotImplementedError, match="gain"):
        sim.run(replan=rcfg)


# --------------------------------------------------------------------- #
# The decision-event channel
# --------------------------------------------------------------------- #


def test_decision_trace_mirrors_decisions():
    """The device telemetry (DecisionTrace) and the host-visible
    decisions list tell the same story, and the joint event channel
    renders one instant per decision."""
    topo, activ, plans, req, qcfg = _switch_world()
    _, fused = _run_both(
        topo, activ, plans, req, qcfg,
        ReplanConfig(mode="backlog", hysteresis=0.0,
                     migration_weight_s_per_mb=0.0))
    tr = fused.report.trace
    assert isinstance(tr, DecisionTrace)
    dec = fused.report.decisions
    assert tr.n_decisions == len(dec)
    assert tr.n_switches == fused.report.n_switches > 0
    np.testing.assert_array_equal(tr.boundaries,
                                  [d.boundary for d in dec])
    np.testing.assert_array_equal(tr.slots, [d.slot for d in dec])
    np.testing.assert_array_equal(tr.chosen, [d.chosen for d in dec])
    np.testing.assert_array_equal(tr.switched, [d.switched for d in dec])
    np.testing.assert_array_equal(tr.migration_bytes,
                                  [d.migration_bytes for d in dec])
    for k, d in enumerate(dec):
        np.testing.assert_array_equal(tr.scores[k], d.scores)
    np.testing.assert_allclose(tr.t_s, tr.boundaries * qcfg.slot_period_s)

    events = joint_decision_events(fused.report)
    assert len(events) == len(dec)
    assert all(e.kind == "joint" for e in events)
    assert sum(e.name == "joint switch" for e in events) \
        == fused.report.n_switches
    # Host reports carry no device telemetry: the channel is empty.
    host_report = dataclasses.replace(fused.report, trace=None)
    assert joint_decision_events(host_report) == []
