"""Loop-aware HLO walker: parser units + validation against XLA's own
cost_analysis (no-multiplier mode) and depth-linearity (multiplier mode)."""
import dataclasses

import jax
import pytest

from repro.compat import cost_analysis
from repro.configs import smoke_config
from repro.launch import hlo_analysis as ha
from repro.models import Parallel, init_params
from repro.models.frontends import batch_specs
from repro.launch.steps import make_train_step, opt_structs

SAMPLE = """\
HloModule test, is_scheduled=true

%cond (arg: (s32[], f32[4,8])) -> pred[] {
  %arg = (s32[], f32[4,8]) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (arg.1: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %arg.1 = (s32[], f32[4,8]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %gte.2 = f32[4,8] get-tuple-element(%arg.1), index=1
  %w = f32[8,8] constant({...})
  %dot.1 = f32[4,8] dot(%gte.2, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,8] all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %next = s32[] add(%gte.1, %one)
  ROOT %tup = (s32[], f32[4,8]) tuple(%next, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[4,8]) tuple(%zero, %x)
  %loop = (s32[], f32[4,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,8] get-tuple-element(%loop), index=1
}
"""


def test_parse_sample_structure():
    comps, entry = ha.parse_hlo(SAMPLE)
    assert entry == "main"
    assert set(comps) == {"cond", "body", "sum", "main"}
    body = comps["body"]
    ops = [i.op for i in body.instructions]
    assert "dot" in ops and "all-reduce" in ops
    assert body.root is not None and body.root.op == "tuple"


def test_multipliers_use_trip_count():
    comps, entry = ha.parse_hlo(SAMPLE)
    mult = ha.computation_multipliers(comps, entry)
    assert mult["main"] == 1.0
    assert mult["body"] == 7.0          # constant(7) in the condition


def test_flops_and_collectives_multiplied():
    cost = ha.analyze(SAMPLE, n_devices=8)
    # dot: 2*4*8*8 = 512 flops per iteration x 7 trips
    assert cost.flops == 7 * 512
    # all-reduce f32[4,8]=128B, ring 2*(g-1)/g with g=4 -> 192B x 7
    assert cost.collective_bytes == pytest.approx(7 * 2 * 128 * 3 / 4)
    assert cost.collective_counts["all-reduce"] == 7
    once = ha.analyze(SAMPLE, n_devices=8, apply_multipliers=False)
    assert once.flops == 512


def test_group_size_formats():
    assert ha._group_size("replica_groups={{0,1,2,3}}", 16) == 4
    assert ha._group_size("replica_groups=[8,2]<=[16]", 16) == 2
    assert ha._group_size("no groups here", 16) == 16


def _compile_train(n_layers: int):
    cfg = dataclasses.replace(smoke_config("smollm-135m"), n_layers=n_layers)
    p = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    o = opt_structs(p)
    batch = batch_specs(cfg, 4, 32)
    step = make_train_step(cfg, Parallel())
    return jax.jit(step).lower(p, o, batch).compile()


def test_walker_matches_xla_cost_analysis_without_multipliers():
    comp = _compile_train(2)
    xla = cost_analysis(comp)
    mine = ha.analyze(comp.as_text(), 1, apply_multipliers=False)
    # XLA counts elementwise flops too; dots dominate => within 15%
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.15
    assert abs(mine.bytes_accessed - xla["bytes accessed"]) \
        / xla["bytes accessed"] < 0.30


@pytest.mark.slow
def test_walker_scales_with_depth_xla_does_not():
    c2 = _compile_train(2)
    c6 = _compile_train(6)
    xla_ratio = cost_analysis(c6)["flops"] / cost_analysis(c2)["flops"]
    m2 = ha.analyze(c2.as_text(), 1).flops
    m6 = ha.analyze(c6.as_text(), 1).flops
    assert xla_ratio < 1.3          # the undercount this module exists for
    assert 2.0 < m6 / m2 < 3.0      # (base + 6u) / (base + 2u)
