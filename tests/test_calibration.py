"""Calibration-layer tests: table determinism + verification, the
analytic-vs-calibrated parity switch (engine and fleet must stay
bit-identical when no service model is passed), batch monotonicity of
the decode rates, satellite-speed validation, the check_bench gate
semantics, and (slow tier) the Eq. 43-vs-measured tolerance harness."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        ServiceModel, evaluate_plans, sample_topology,
                        spacemoe_plan)
from repro.core import calibration as cal
from repro.core.calibration import resolve_service_model

CFG = ConstellationConfig.scaled(8, 12, n_slots=10)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()

#: Small enough that measure_components runs in well under a second —
#: the tier-1 tests time real kernels, just tiny ones.
TINY = MoEWorkload(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff_expert=128, n_experts=4, top_k=2, vocab_size=512)
TINY_CTX = 32
TINY_BATCHES = (1, 2, 4)


@pytest.fixture(scope="module")
def tiny_measured():
    return cal.measure_components(TINY, TINY_CTX, TINY_BATCHES, "ref",
                                  iters=1, rows_per_expert=1)


@pytest.fixture(scope="module")
def tiny_table(tiny_measured):
    return cal.derive_table("tiny", TINY, tiny_measured, TINY_CTX,
                            TINY_BATCHES, COMP)


def _world(seed=0, n_layers=4, n_experts=4, top_k=2):
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    return con, topo, activ


# --------------------------------------------------------------------- #
# Table derivation: determinism, round-trip, committed-table integrity
# --------------------------------------------------------------------- #


def test_derive_table_deterministic(tiny_measured):
    """Same measurements in -> bitwise-identical table and hash out."""
    t1 = cal.derive_table("tiny", TINY, tiny_measured, TINY_CTX,
                          TINY_BATCHES, COMP)
    t2 = cal.derive_table("tiny", TINY, tiny_measured, TINY_CTX,
                          TINY_BATCHES, COMP)
    assert t1.table_hash == t2.table_hash
    assert t1.to_dict() == t2.to_dict()
    assert cal.verify_table(t1, COMP)


def test_table_roundtrip_and_tamper_detection(tiny_table, tmp_path):
    path = cal.save_table(tiny_table, table_dir=tmp_path)
    assert path.exists()
    loaded = cal.load_table("tiny", table_dir=tmp_path)
    assert loaded.table_hash == tiny_table.table_hash
    assert loaded.to_dict() == tiny_table.to_dict()
    # a tampered service number must not load silently
    import json
    d = json.loads(path.read_text())
    d["derived"]["expert_s"][0] *= 2.0
    path.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="hash"):
        cal.load_table("tiny", table_dir=tmp_path)


def test_committed_tables_verify():
    """Every table shipped under calibration_tables/ re-derives exactly
    from its own stored measurements (the CI freshness gate)."""
    names = cal.list_tables()
    assert len(names) >= 2
    for name in names:
        table = cal.load_table(name)
        assert table.version == cal.TABLE_VERSION
        assert table.table_hash == table.compute_hash()
        assert cal.verify_table(table)
        w = table.workload_obj()
        assert w.n_experts == len(table.derived["expert_s"])


# --------------------------------------------------------------------- #
# Analytic parity: service_model=None must stay bit-identical
# --------------------------------------------------------------------- #


def test_engine_analytic_parity_bitwise():
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    rngs = (np.random.default_rng(3) for _ in range(3))
    base, named, explicit = (
        evaluate_plans([plan], topo, activ, WL, COMP, next(rngs),
                       n_tokens=150, service_model=sm)[0]
        for sm in (None, "analytic", ServiceModel.analytic(WL, COMP)))
    for r in (named, explicit):
        np.testing.assert_array_equal(r.layer_latency_s,
                                      base.layer_latency_s)
        np.testing.assert_array_equal(r.delivered, base.delivered)
        np.testing.assert_array_equal(r.token_latency_s,
                                      base.token_latency_s)


def test_fleet_analytic_parity_bitwise():
    from repro.traffic import FleetSim, QueueConfig, RequestBatch
    con, topo, activ = _world()
    plans = [spacemoe_plan(con, topo, activ)]
    req = RequestBatch(
        arrival_s=np.arange(12) * 15.0,
        prompt_len=np.full(12, 1, dtype=np.int64),
        decode_len=np.full(12, 5, dtype=np.int64),
        station=np.zeros(12, dtype=np.int64),
    )
    runs = []
    for sm in (None, ServiceModel.analytic(WL, COMP)):
        sim = FleetSim(plans, topo, activ, WL, COMP, req,
                       np.random.default_rng(0), QueueConfig(),
                       service_model=sm)
        runs.append(sim.run_legacy().plans[0])
    assert runs[0].goodput_tok_s == runs[1].goodput_tok_s
    assert runs[0].quantile("ttft", 0.5) == runs[1].quantile("ttft", 0.5)


def test_engine_calibrated_mode_runs_and_differs(tiny_table):
    """A calibrated model flows through evaluate_plans: finite positive
    latencies that differ from the analytic trace."""
    con, topo, activ = _world()
    plan = spacemoe_plan(con, topo, activ)
    svc = ServiceModel.calibrated(WL, COMP, _retarget(tiny_table, WL))
    base = evaluate_plans([plan], topo, activ, WL, COMP,
                          np.random.default_rng(5), n_tokens=80)[0]
    calib = evaluate_plans([plan], topo, activ, WL, COMP,
                           np.random.default_rng(5), n_tokens=80,
                           service_model=svc)[0]
    lat = calib.layer_latency_s[calib.delivered]
    assert np.all(np.isfinite(lat)) and np.all(lat > 0)
    assert not np.array_equal(calib.layer_latency_s, base.layer_latency_s)


def _retarget(table, workload):
    """Re-key a tiny table's derived experts onto another workload's
    expert count (service numbers stay the tiny ones — the engine only
    needs per-expert seconds, not matching shapes elsewhere)."""
    d = dict(table.derived)
    d["expert_s"] = [d["expert_s"][0]] * workload.n_experts
    w = dataclasses.asdict(workload)
    t = dataclasses.replace(table, derived=d, workload=w, table_hash=None)
    return dataclasses.replace(t, table_hash=t.compute_hash())


# --------------------------------------------------------------------- #
# Batch-size-dependent decode rates off the attention roofline
# --------------------------------------------------------------------- #


def test_decode_rate_monotone_in_batch(tiny_table):
    svc = ServiceModel.calibrated(TINY, COMP, tiny_table)
    b = np.array([1, 2, 4, 8, 16, 32], dtype=np.float64)
    rates = svc.decode_rate(b, ctx_len=TINY_CTX)
    assert np.all(np.isfinite(rates)) and np.all(rates > 0)
    assert np.all(np.diff(rates) >= -1e-12)           # tokens/s grows with B
    per_tok = svc.gateway_s(TINY_CTX, b)
    assert np.all(np.diff(per_tok) <= 1e-12)          # amortization helps


def test_host_units_exact_lookup(tiny_table, tiny_measured):
    """Host units at a swept (ctx, B) point return the measured kernel
    timing itself; off-grid batches fall back to the roofline."""
    svc = ServiceModel.calibrated(TINY, COMP, tiny_table, units="host")
    ms = tiny_measured["measured_s"]["gateway_by_batch"]
    for b in TINY_BATCHES:
        assert svc.gateway_step_s(TINY_CTX, b) == pytest.approx(ms[str(b)])
    assert np.isfinite(svc.gateway_step_s(TINY_CTX, 3))   # off-grid
    assert svc.expert_s()[0] == pytest.approx(
        tiny_measured["measured_s"]["expert_visit"])


# --------------------------------------------------------------------- #
# Validation & resolution errors
# --------------------------------------------------------------------- #


def test_sat_speed_validation(tiny_table):
    svc = ServiceModel.calibrated(TINY, COMP, tiny_table,
                                  sat_speed=(1.0, 2.0, 0.5))
    inv = svc.inv_speed(3)
    np.testing.assert_allclose(inv, [1.0, 0.5, 2.0])
    with pytest.raises(ValueError, match="entries"):
        svc.inv_speed(4)
    with pytest.raises(ValueError, match="positive"):
        ServiceModel.calibrated(TINY, COMP, tiny_table,
                                sat_speed=(1.0, -1.0)).inv_speed(2)


def test_resolve_and_constructor_errors(tiny_table):
    assert resolve_service_model(None, WL, COMP).mode == "analytic"
    assert resolve_service_model("analytic", WL, COMP).mode == "analytic"
    with pytest.raises(ValueError, match="ServiceModel instance"):
        resolve_service_model("calibrated", WL, COMP)
    with pytest.raises(TypeError):
        resolve_service_model(42, WL, COMP)
    with pytest.raises(ValueError, match="units"):
        ServiceModel.calibrated(TINY, COMP, tiny_table, units="warp")
    with pytest.raises(ValueError, match="experts"):
        ServiceModel.calibrated(WL, COMP, tiny_table)   # 4 != WL's experts


def test_provenance_reports_loaded_tables():
    cal.load_table(cal.list_tables()[0])
    prov = cal.provenance()
    assert prov["table_version"] == cal.TABLE_VERSION
    assert prov["tables"]                      # at least the one above
    for name, h in prov["tables"].items():
        assert len(h) == 16


# --------------------------------------------------------------------- #
# check_bench gate semantics
# --------------------------------------------------------------------- #


def test_check_bench_diff_semantics():
    from tools.check_bench import diff
    base = {"goodput_tok_s": 10.0, "parity_ok": True, "n": 5,
            "ttft_p99_s": 1.0, "_provenance": {"jax": "x"}}
    fresh_ok = {"goodput_tok_s": 10.4, "parity_ok": True, "n": 5,
                "ttft_p99_s": 99.0, "_provenance": {"jax": "y"},
                "new_metric": 1.0}
    assert diff(fresh_ok, base) == []          # 4% goodput, skipped keys
    assert diff({**fresh_ok, "goodput_tok_s": 11.0}, base)   # 10% fails
    assert diff({**fresh_ok, "parity_ok": False}, base)      # bool gate
    missing = dict(fresh_ok)
    del missing["n"]
    assert any("missing" in p for p in diff(missing, base))


# --------------------------------------------------------------------- #
# The model-in-the-loop harness (slow tier; CI nightly + calibration job)
# --------------------------------------------------------------------- #


@pytest.mark.slow
def test_eq43_predictions_match_measured_decode():
    """Real sharded decode vs engine Eq. 43 predictions within the
    documented factor bound, on the first harness config."""
    from benchmarks import bench_calibration as bc
    rec = bc.validate_config(bc.HARNESS_ARCHS[0], n_tokens=6, iters=2)
    assert rec["pass"], (
        f"worst per-layer factor {rec['worst_ratio']:.2f} outside "
        f"[1/{bc.TOLERANCE}, {bc.TOLERANCE}]")
    for layer in rec["layers"]:
        assert layer["measured_s"] > 0 and layer["predicted_s"] > 0
