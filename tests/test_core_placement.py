"""Unit tests: objective closed form, Theorem 1 optimality, placement plans,
constellation geometry, simulator orderings."""
import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        activation_probs, brute_force_optimal,
                        central_gateway, layer_latency_closed_form,
                        layer_latency_monte_carlo, multi_expert_plan,
                        rand_intra_cg_plan, rand_intra_plan, rand_place_plan,
                        ring_subnets, sample_topology,
                        simulate_token_generation, spacemoe_plan,
                        theorem1_assignment)

# --------------------------------------------------------------------- #
# Objective (Lemma 1 + 2)
# --------------------------------------------------------------------- #


def test_closed_form_matches_monte_carlo():
    rng = np.random.default_rng(0)
    tau = np.sort(rng.uniform(0.01, 0.2, size=6))
    w = rng.gamma(2.0, 1.0, size=6) + 0.1
    perm = rng.permutation(6)
    cf = layer_latency_closed_form(tau, w, perm, 2)
    mc = layer_latency_monte_carlo(tau, w, perm, 2, np.random.default_rng(1), 60000)
    assert abs(cf - mc) / cf < 0.01


def test_closed_form_k_equals_i():
    # K = I: the slowest rank is always I, so tau_c = tau_max.
    tau = np.array([0.1, 0.2, 0.7])
    w = np.array([1.0, 2.0, 3.0])
    val = layer_latency_closed_form(tau, w, np.arange(3), 3)
    assert np.isclose(val, 0.7)


def test_closed_form_uniform_weights_placement_invariant():
    tau = np.array([0.1, 0.2, 0.3, 0.4])
    w = np.ones(4)
    vals = {
        layer_latency_closed_form(tau, w, np.asarray(p), 2)
        for p in ([0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2])
    }
    assert max(vals) - min(vals) < 1e-12


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n,k", [(5, 2), (6, 3)])
def test_theorem1_is_brute_force_optimal(seed, n, k):
    """Theorem 1 sort-and-match == exhaustive search over all I! placements."""
    rng = np.random.default_rng(seed)
    tau = np.sort(rng.uniform(0.01, 0.3, size=n))
    w = rng.gamma(2.0, 1.0, size=n) + 0.05
    probs = activation_probs(w, k)
    assign = theorem1_assignment(probs, tau)      # expert -> rank
    rank_to_expert = np.empty(n, dtype=np.int64)
    rank_to_expert[assign] = np.arange(n)
    thm = layer_latency_closed_form(tau, w, rank_to_expert, k)
    _, best = brute_force_optimal(tau, w, k)
    assert thm <= best + 1e-12


def test_theorem1_uses_lowest_latency_prefix():
    probs = np.array([0.9, 0.1, 0.5])
    tau = np.array([5.0, 1.0, 3.0, 2.0, 10.0])   # candidates, unsorted
    assign = theorem1_assignment(probs, tau)
    # hottest expert 0 -> candidate 1 (tau=1); expert 2 -> candidate 3 (tau=2);
    # coldest expert 1 -> candidate 2 (tau=3)
    np.testing.assert_array_equal(assign, [1, 2, 3])


def test_theorem1_rejects_insufficient_candidates():
    with pytest.raises(ValueError):
        theorem1_assignment(np.array([0.5, 0.5]), np.array([1.0]))


# --------------------------------------------------------------------- #
# Constellation geometry + topology
# --------------------------------------------------------------------- #

CFG = ConstellationConfig.scaled(8, 12, n_slots=10)


def test_positions_on_shell():
    con = Constellation(CFG)
    pos = con.positions(123.4)
    np.testing.assert_allclose(
        np.linalg.norm(pos, axis=-1), CFG.semi_major_axis_m, rtol=1e-12
    )


def test_edge_degree_at_most_four():
    con = Constellation(CFG)
    deg = np.zeros(CFG.n_sats, dtype=int)
    for u, v in con.edges:
        deg[u] += 1
        deg[v] += 1
    assert deg.max() <= 4
    # intra-orbit ring + inter-orbit (incl. candidate seam) edge counts
    assert con.intra_orbit_mask.sum() == CFG.n_sats
    assert con.seam_mask.sum() == CFG.sats_per_plane


def test_corotating_links_always_trackable_at_paper_threshold():
    con = Constellation(CFG)
    for t in [0.0, CFG.orbital_period_s / 3]:
        feas = con.tracking_feasible(t)
        assert feas[~con.seam_mask].all()


def test_seam_links_mostly_gated():
    con = Constellation(CFG)
    seam_up = []
    for t in CFG.slot_times():
        seam_up.append(con.tracking_feasible(float(t))[con.seam_mask])
    frac = np.concatenate(seam_up).mean()
    assert frac < 0.7  # Earth occlusion + PAT kill most seam slots


def test_topology_sample_shapes_and_availability():
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    assert topo.edge_mask.shape == (CFG.n_slots, len(con.edges))
    assert 0.7 < topo.availability() <= CFG.survival_prob + 0.02
    assert (topo.edge_latency > 0).all()


def test_shortest_path_properties():
    con = Constellation(CFG)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(1))
    d = topo.distances_from(0, np.arange(6))
    assert d.shape == (6, CFG.n_sats)
    assert (d[np.arange(6), np.arange(6)] == 0).all()
    finite = np.isfinite(d)
    assert (d[finite] >= 0).all()
    # one-hop neighbours: shortest path <= direct edge latency
    m = topo.edge_mask[0]
    for (u, v), lat in zip(topo.edges[m][:50], topo.edge_latency[0][m][:50]):
        if u < 6:
            assert d[u, v] <= lat + 1e-12


# --------------------------------------------------------------------- #
# Two-level placement
# --------------------------------------------------------------------- #


def test_ring_subnets_disjoint_cover():
    subnets = ring_subnets(CFG, 4)
    allnodes = np.concatenate(subnets)
    assert len(np.unique(allnodes)) == len(allnodes)
    assert len(allnodes) == CFG.n_planes * (CFG.sats_per_plane // 4) * 4
    # Eq. 17: subnet l spans y in [l*y_span, (l+1)*y_span)
    y = subnets[1] % CFG.sats_per_plane
    span = CFG.sats_per_plane // 4
    assert y.min() == span and y.max() == 2 * span - 1


def test_ring_subnets_requires_enough_rings():
    with pytest.raises(ValueError):
        ring_subnets(CFG, CFG.sats_per_plane + 1)


def test_central_gateway_inside_subnet():
    subnets = ring_subnets(CFG, 4)
    for layer in range(4):
        g = central_gateway(CFG, layer, 4)
        assert g in subnets[layer]


def _small_world():
    cfg = ConstellationConfig.scaled(8, 12, n_slots=10)
    con = Constellation(cfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(n_layers=4, n_experts=4, top_k=2, seed=1)
    return cfg, con, topo, activ


def test_plans_are_injective_and_in_subnet():
    cfg, con, topo, activ = _small_world()
    plan = spacemoe_plan(con, topo, activ)
    plan.validate(cfg.n_sats)
    subnets = ring_subnets(cfg, 4)
    for layer in range(4):
        assert set(plan.expert_sats[layer]).issubset(set(subnets[layer]))
        assert plan.gateways[layer] == central_gateway(cfg, layer, 4)
    for maker, seed in [(rand_place_plan, 2), (rand_intra_plan, 3),
                        (rand_intra_cg_plan, 4)]:
        p = maker(cfg, 4, 4, np.random.default_rng(seed))
        p.validate(cfg.n_sats)


def test_spacemoe_hot_experts_on_low_latency_sats():
    _, con, topo, activ = _small_world()
    plan = spacemoe_plan(con, topo, activ)
    for layer in range(4):
        probs = activ.probs(layer)
        order = np.argsort(-probs, kind="stable")
        ranks = plan.expert_rank[layer][order]
        assert (np.diff(ranks) > 0).all()      # hotter expert => lower rank
        assert ranks[0] == 0                   # hottest on the best satellite


def test_simulator_reproduces_paper_ordering():
    """Expected ordering RandPlace > RandIntra > RandIntra-CG > SpaceMoE.

    Random baselines are averaged over placement draws (the paper compares
    expectations; a single draw at this toy scale is within noise).
    """
    cfg, con, topo, activ = _small_world()
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()

    def mean_over_draws(maker, n_draws=5):
        vals = []
        for s in range(n_draws):
            plan = maker(cfg, 4, 4, np.random.default_rng(100 + s))
            r = simulate_token_generation(
                plan, topo, activ, wl, comp, np.random.default_rng(5), 300
            )
            assert r.layer_latency_s.shape == (300, 4)
            assert r.drop_rate < 0.05
            vals.append(r.mean_s)
        return float(np.mean(vals))

    sm = simulate_token_generation(
        spacemoe_plan(con, topo, activ, wl, comp), topo, activ, wl, comp,
        np.random.default_rng(5), 300,
    ).mean_s
    rand_place = mean_over_draws(rand_place_plan)
    rand_intra = mean_over_draws(rand_intra_plan)
    rand_cg = mean_over_draws(rand_intra_cg_plan)
    assert sm < rand_cg < rand_intra < rand_place


def test_link_state_staleness_costs_latency():
    """Sec. VIII extension: stale routing tables can only hurt, and the
    zero-staleness path equals the default simulator."""
    cfg, con, topo, activ = _small_world()
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()
    plan = spacemoe_plan(con, topo, activ, wl, comp)
    base = simulate_token_generation(
        plan, topo, activ, wl, comp, np.random.default_rng(5), 200)
    fresh = simulate_token_generation(
        plan, topo, activ, wl, comp, np.random.default_rng(5), 200,
        route_staleness=0, reroute_penalty_s=0.03)
    assert np.isclose(base.mean_s, fresh.mean_s)
    stale = simulate_token_generation(
        plan, topo, activ, wl, comp, np.random.default_rng(5), 200,
        route_staleness=3, reroute_penalty_s=0.03)
    assert stale.mean_s >= base.mean_s - 1e-12


def test_multi_expert_plans():
    cfg, con, topo, activ = _small_world()
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()
    for mode in ["slotted", "spread"]:
        mp = multi_expert_plan(con, topo, activ, experts_per_sat=2, mode=mode)
        assert mp.expert_sats.shape == (4, 4)
        # at most N_E experts per satellite per layer
        for layer in range(4):
            _, counts = np.unique(mp.expert_sats[layer], return_counts=True)
            assert counts.max() <= 2
        r = simulate_token_generation(
            mp, topo, activ, wl, comp, np.random.default_rng(6), n_tokens=100
        )
        assert np.isfinite(r.mean_s)
    # compute-limited: spreading beats stacking when eta is small
    slotted = multi_expert_plan(con, topo, activ, 2, "slotted")
    spread = multi_expert_plan(con, topo, activ, 2, "spread")
    slow = ComputeConfig(peak_gflops=0.5)
    r_sl = simulate_token_generation(slotted, topo, activ, wl, slow,
                                     np.random.default_rng(7), 300, eta=1.0)
    r_sp = simulate_token_generation(spread, topo, activ, wl, slow,
                                     np.random.default_rng(7), 300, eta=1.0)
    assert r_sp.mean_s <= r_sl.mean_s + 1e-9
