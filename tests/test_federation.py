"""Planet-scale federation guards: one compile trace for a K-member
federation under a nested rate sweep, bitwise per-member parity with
standalone FleetSim runs when overflow is off, monotone overflow
routing with TTFT-billed inter-constellation forwards, shared-bin-grid
construction, member validation, and the sharded million-user-scale
arrival/streaming machinery (envelope violation regression included)."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FederationConfig, FederationSim,
                           FleetSim, QueueConfig, build_federation,
                           build_ground_segment, sample_requests,
                           stream_arrivals, stream_requests,
                           thinned_arrivals)
from repro.traffic import queueing

CFG = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
WL = MoEWorkload.llama_moe_3p5b()
COMP = ComputeConfig()


def _factory(seed, req, qcfg, n_plans=1):
    """One member world: own topology draw + ground visibility + plans."""
    def build(min_bins=0):
        con = Constellation(CFG)
        topo = sample_topology(con, LinkConfig(),
                               np.random.default_rng(seed))
        activ = ActivationModel.zipf(4, 4, 2, seed=1)
        ground = build_ground_segment(con, LinkConfig(),
                                      min_elevation_deg=10.0)
        plans = [spacemoe_plan(con, topo, activ)]
        if n_plans > 1:
            plans.append(rand_intra_cg_plan(CFG, 4, 4,
                                            np.random.default_rng(seed)))
        return FleetSim(plans, topo, activ, WL, COMP, req,
                        np.random.default_rng(5), qcfg=qcfg,
                        ground=ground, min_bins=min_bins)
    return build


def _requests(horizon_s, rate_rps, seed=8):
    return sample_requests(np.random.default_rng(seed), rate_rps=rate_rps,
                           horizon_s=horizon_s, n_stations=8,
                           prompt_median=4, prompt_max=16, decode_mean=4,
                           decode_max=8)


def _federation(horizon_s=40.0, rate_rps=4.0, ttft_target=8.0, seeds=(0, 1, 2),
                n_plans=(1, 1, 1), **fed_kwargs):
    req = _requests(horizon_s, rate_rps)
    qcfg = QueueConfig(dt_s=0.05, tail_s=40.0,
                       admission=AdmissionConfig(ttft_target_s=ttft_target))
    return build_federation(
        [_factory(s, req, qcfg, n_plans=p) for s, p in zip(seeds, n_plans)],
        **fed_kwargs), req


# --------------------------------------------------------------------- #
# One launch for the whole federation
# --------------------------------------------------------------------- #


def test_federation_nested_sweep_is_one_trace():
    """K=3 members under a 2-point nested rate sweep (6 lanes) compile
    exactly one new trace of the fused kernel — overflow re-launches
    reuse the cache entry — and a same-shape rerun compiles none."""
    fed, req = _federation(horizon_s=37.0, rate_rps=3.7)
    masks = np.stack([
        np.ones(req.n_requests, dtype=bool),
        np.random.default_rng(0).random(req.n_requests) < 0.5])
    before = queueing.FUSED_TRACE_COUNT
    results = fed.run_many(masks)
    assert queueing.FUSED_TRACE_COUNT == before + 1
    assert len(results) == 2
    assert results[0].n_rounds >= 1
    before = queueing.FUSED_TRACE_COUNT
    fed.run_many(masks)
    assert queueing.FUSED_TRACE_COUNT == before


# --------------------------------------------------------------------- #
# Bitwise parity with standalone members (overflow off)
# --------------------------------------------------------------------- #


def test_overflow_off_members_bitwise_match_standalone_runs():
    """With overflow disabled, every member's per-plan outcome — across
    both sweep entries and across members of *different* plan counts
    (exercising the edge-repeat plan padding) — is bitwise identical to
    running that FleetSim alone on its home slice of the trace."""
    fed, req = _federation(horizon_s=41.0, n_plans=(2, 1, 1))
    assert fed._p_max == 2                 # padding genuinely exercised
    masks = np.stack([
        np.ones(req.n_requests, dtype=bool),
        np.random.default_rng(1).random(req.n_requests) < 0.6])
    results = fed.run_many(masks, overflow=False)
    for s in range(2):
        for k, sim in enumerate(fed.sims):
            alone = sim.run(masks[s] & (fed.home == k))
            for pf, pa in zip(results[s].members[k].plans, alone.plans):
                np.testing.assert_array_equal(pf.served, pa.served)
                np.testing.assert_array_equal(pf.shed, pa.shed)
                np.testing.assert_array_equal(pf.retries, pa.retries)
                np.testing.assert_array_equal(pf.ttft_s, pa.ttft_s)
                np.testing.assert_array_equal(pf.e2e_s, pa.e2e_s)
                np.testing.assert_array_equal(pf.tpot_s, pa.tpot_s)
                np.testing.assert_array_equal(pf.station_util,
                                              pa.station_util)
                np.testing.assert_array_equal(pf.token_total_s,
                                              pa.token_total_s)


# --------------------------------------------------------------------- #
# Overflow routing: monotone fixed point + latency billing
# --------------------------------------------------------------------- #


def test_overflow_reroutes_shed_requests_and_converges():
    """Under a shedding load, overflow moves rejected requests to the
    next-ranked member: the pooled shed set shrinks versus independent
    operation, the fixed point converges within K rounds, offered masks
    stay disjoint (a request is never served twice), hops respect the
    K-1 budget, and rejections are permanent (hops only count forward
    moves along each request's ranking)."""
    fed, req = _federation(horizon_s=43.0, rate_rps=4.3)
    off = fed.run(overflow=False)
    on = fed.run(overflow=True)
    assert off.federated.shed.sum() > 0            # load genuinely sheds
    assert on.federated.shed.sum() < off.federated.shed.sum()
    assert (on.hops > 0).any()
    assert on.n_rounds <= fed.n_members
    assert (on.hops <= fed.n_members - 1).all()
    # Disjoint final offers: each request sits at <= 1 member.
    assert (on.offered.sum(axis=0) <= 1).all()
    # Requests that overflowed and got served landed on a member that
    # ranks *after* their home in their own preference order.
    moved = (on.hops > 0) & on.federated.served
    assert moved.any()
    for r in np.flatnonzero(moved)[:50]:
        rank = list(fed.ranking[r])
        assert rank.index(on.assigned[r]) >= on.hops[r]
    # Serving members' outcomes stay internally consistent: every
    # served overflow request has finite billed latencies.
    assert np.isfinite(on.federated.ttft_s[moved]).all()
    assert np.isfinite(on.federated.e2e_s[moved]).all()


def test_overflow_forward_latency_bills_ttft_not_tpot():
    """Raising the forwarding delay shifts a rerouted request's TTFT and
    E2E by exactly hops * delta and leaves TPOT bitwise unchanged
    (routing itself is delay-independent, so the two runs serve
    identical sets)."""
    fed, req = _federation(horizon_s=47.0, rate_rps=4.1)
    lo = fed.run()                                   # derived default delay
    hi_cfg = FederationConfig(forward_delay_s=fed.forward_delay_s + 2.5)
    fed_hi = FederationSim(fed.sims, hi_cfg, home=None)
    hi = fed_hi.run()
    np.testing.assert_array_equal(lo.federated.served, hi.federated.served)
    np.testing.assert_array_equal(lo.hops, hi.hops)
    served = lo.federated.served
    shift = lo.hops * 2.5
    np.testing.assert_allclose(hi.federated.ttft_s[served],
                               lo.federated.ttft_s[served] + shift[served],
                               rtol=0, atol=1e-9)
    np.testing.assert_allclose(hi.federated.e2e_s[served],
                               lo.federated.e2e_s[served] + shift[served],
                               rtol=0, atol=1e-9)
    np.testing.assert_array_equal(lo.federated.tpot_s, hi.federated.tpot_s)
    assert (lo.hops > 0).any()                       # billing exercised


def test_home_override_concentrates_load():
    """An explicit home vector pins every feasible request on one member
    (the hotspot-bench pattern); infeasible homes fall back to the cost
    ranking."""
    fed, req = _federation(horizon_s=38.0)
    home = np.zeros(req.n_requests, dtype=np.int64)
    fed_hot = FederationSim(fed.sims, FederationConfig(), home=home)
    feasible0 = fed_hot.feasible[0]
    assert (fed_hot.home[feasible0] == 0).all()
    res = fed_hot.run(overflow=False)
    # Everything feasible-at-0 is offered to member 0 and nothing else.
    np.testing.assert_array_equal(res.offered[0], feasible0)
    assert not res.offered[1:].any() or (
        fed_hot.home[res.offered[1:].any(axis=0)] != 0).all()


# --------------------------------------------------------------------- #
# Construction: shared bin grid + member validation
# --------------------------------------------------------------------- #


def test_build_federation_equalizes_bin_grids():
    """Members whose natural horizons disagree are rebuilt on the
    federation-wide bin grid (the fused kernel's T is static)."""
    req = _requests(40.0, 2.0)
    q_short = QueueConfig(dt_s=0.05, tail_s=20.0,
                          admission=AdmissionConfig(ttft_target_s=10.0))
    q_long = QueueConfig(dt_s=0.05, tail_s=60.0,
                         admission=AdmissionConfig(ttft_target_s=10.0))
    fed = build_federation([_factory(0, req, q_short),
                            _factory(1, req, q_long)])
    assert fed.sims[0].n_bins == fed.sims[1].n_bins
    # Direct construction with mismatched grids refuses loudly.
    with pytest.raises(ValueError, match="time bins"):
        FederationSim([_factory(0, req, q_short)(),
                       _factory(1, req, q_long)()])


def test_validation_rejects_incompatible_members():
    req = _requests(35.0, 2.0)
    qcfg = QueueConfig(dt_s=0.05, tail_s=40.0,
                       admission=AdmissionConfig(ttft_target_s=10.0))
    base = _factory(0, req, qcfg)()
    # Different request trace.
    other_req = _requests(35.0, 2.0, seed=9)
    with pytest.raises(ValueError, match="request trace"):
        FederationSim([base, _factory(1, other_req, qcfg)()])
    # Admission on one member only.
    q_off = dataclasses.replace(qcfg, admission=None)
    with pytest.raises(ValueError, match="admission"):
        FederationSim([base, _factory(1, req, q_off)()])
    # Overflow needs the controller.
    with pytest.raises(ValueError, match="overflow"):
        FederationSim([_factory(0, req, q_off)(),
                       _factory(1, req, q_off)()],
                      FederationConfig(overflow=True))
    # Different controller law.
    q_law = dataclasses.replace(
        qcfg, admission=AdmissionConfig(ttft_target_s=10.0, decrease=0.3))
    with pytest.raises(ValueError, match="admission law"):
        FederationSim([base, _factory(1, req, q_law)()])
    # Per-member *targets* are explicitly allowed.
    q_tgt = dataclasses.replace(
        qcfg, admission=AdmissionConfig(ttft_target_s=25.0))
    FederationSim([base, _factory(1, req, q_tgt)()])


# --------------------------------------------------------------------- #
# Million-user-scale input machinery (satellites 1 + 2)
# --------------------------------------------------------------------- #


def test_thinned_arrivals_rejects_envelope_violation():
    """Regression: a rate_fn exceeding the envelope used to silently
    saturate the keep-probability at 1 and bias the trace low — now it
    raises, and clip=True downgrades to a warning."""
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="envelope"):
        thinned_arrivals(lambda t: np.full_like(t, 3.0), 2.0, 50.0, rng)
    with pytest.warns(RuntimeWarning, match="envelope"):
        t = thinned_arrivals(lambda t: np.full_like(t, 3.0), 2.0, 50.0,
                             rng, clip=True)
    assert (np.diff(t) >= 0).all()
    # A rate_fn that merely *touches* the envelope stays legal
    # (float-rounding tolerance).
    thinned_arrivals(lambda t: np.full_like(t, 2.0), 2.0, 50.0, rng)


def test_stream_arrivals_bounded_shards_match_envelope_semantics():
    rng = np.random.default_rng(3)
    rate_fn = lambda t: 20.0 * (1.0 + 0.5 * np.sin(t / 30.0))  # noqa: E731
    times, n_env = stream_arrivals(rate_fn, 30.0, 900.0, rng, shard_s=100.0)
    assert (np.diff(times) >= 0).all()
    assert times.size and 0.0 <= times[0] and times[-1] < 900.0
    assert n_env >= times.size                     # thinning only removes
    # Rate sanity: kept arrivals approximate the integrated rate.
    expect = 20.0 * 900.0 + 20.0 * 0.5 * 30.0 * (1 - np.cos(900.0 / 30.0))
    assert abs(times.size - expect) / expect < 0.05
    # Envelope violations raise exactly like the unsharded path.
    with pytest.raises(ValueError, match="envelope"):
        stream_arrivals(lambda t: np.full_like(t, 40.0), 30.0, 100.0,
                        np.random.default_rng(0))


def test_stream_requests_builds_valid_batch():
    rng = np.random.default_rng(11)
    req, n_env = stream_requests(rng, lambda t: np.full_like(t, 25.0),
                                 30.0, 400.0, n_stations=8, shard_s=50.0)
    assert n_env >= req.n_requests
    assert (np.diff(req.arrival_s) >= 0).all()
    assert (req.station >= 0).all() and (req.station < 8).all()
    assert (req.prompt_len >= 1).all() and (req.decode_len >= 1).all()


def test_request_of_token_memo_invalidates_on_replace():
    req = _requests(30.0, 1.0)
    a = req.request_of_token()
    b = req.request_of_token()
    assert a is b                                   # memo hit
    assert not a.flags.writeable                    # shared copy is frozen
    np.testing.assert_array_equal(
        a, np.repeat(np.arange(req.n_requests), req.decode_len))
    sub = req.subset(np.arange(req.n_requests) % 2 == 0)
    c = sub.request_of_token()
    assert c is not a
    np.testing.assert_array_equal(
        c, np.repeat(np.arange(sub.n_requests), sub.decode_len))
